//! Minimal benchmark harness (offline substrate for `criterion`).
//!
//! Each `benches/*.rs` target is a `harness = false` binary built on this
//! module: [`Bencher`] measures a closure with warm-up + timed iterations
//! and prints a stats line; [`BenchReport`] collects named results and can
//! render a markdown-ish summary table plus machine-readable JSON (used by
//! EXPERIMENTS.md tooling and persisted as `BENCH_*.json` at the repo root
//! so the perf trajectory is tracked across PRs).
//!
//! Setting `FSTENCIL_BENCH_SMOKE=1` puts every bench target into *smoke
//! mode* ([`smoke`], [`Bencher::from_env`]): one sample, no warm-up, tiny
//! problem sizes — CI runs each target this way so bench bit-rot is caught
//! at PR time without paying measurement-grade runtimes.

use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::Summary;
use crate::util::table::Table;

/// Timing configuration.
#[derive(Debug, Clone, Copy)]
pub struct Bencher {
    pub warmup_iters: usize,
    pub sample_iters: usize,
    /// Cap total sampling time; long benches stop early once exceeded.
    pub max_time: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup_iters: 2, sample_iters: 10, max_time: Duration::from_secs(20) }
    }
}

/// Whether smoke mode is requested (`FSTENCIL_BENCH_SMOKE` set to anything
/// but `0`/empty). Bench targets consult this to shrink their grids.
pub fn smoke() -> bool {
    std::env::var("FSTENCIL_BENCH_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false)
}

impl Bencher {
    /// Default timing config, or a single-sample no-warm-up config when
    /// [`smoke`] mode is on.
    pub fn from_env() -> Bencher {
        if smoke() {
            Bencher { warmup_iters: 0, sample_iters: 1, max_time: Duration::from_secs(2) }
        } else {
            Bencher::default()
        }
    }
}

/// One benchmark's outcome.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
    /// Optional derived metric (e.g. Mcells/s) with its unit.
    pub metric: Option<(f64, &'static str)>,
}

impl BenchResult {
    pub fn line(&self) -> String {
        let s = &self.summary;
        let extra = self
            .metric
            .map(|(v, u)| format!("  ({v:.2} {u})"))
            .unwrap_or_default();
        format!(
            "{:<44} {:>10.3} ms/iter  ±{:>6.2}%  (n={}){extra}",
            self.name,
            s.mean * 1e3,
            s.rsd() * 100.0,
            s.n
        )
    }
}

impl Bencher {
    /// Time `f`, returning per-iteration seconds.
    pub fn bench<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.sample_iters);
        let start = Instant::now();
        for _ in 0..self.sample_iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
            if start.elapsed() > self.max_time && samples.len() >= 3 {
                break;
            }
        }
        let summary = Summary::of(&samples).expect("at least one sample");
        let r = BenchResult { name: name.to_string(), summary, metric: None };
        println!("{}", r.line());
        r
    }

    /// Bench and attach a throughput metric computed from mean time.
    pub fn bench_with_metric<F: FnMut()>(
        &self,
        name: &str,
        unit: &'static str,
        per_iter_units: f64,
        mut f: F,
    ) -> BenchResult {
        let mut r = self.bench(name, &mut f);
        r.metric = Some((per_iter_units / r.summary.mean, unit));
        println!("{}", r.line());
        r
    }
}

/// Collects results for a whole bench target and renders the summary.
#[derive(Debug, Default)]
pub struct BenchReport {
    pub title: String,
    pub results: Vec<BenchResult>,
    /// Free-form table/figure payload printed verbatim (e.g. the Table 4
    /// reproduction the bench regenerates).
    pub payload: Vec<String>,
}

impl BenchReport {
    pub fn new(title: &str) -> BenchReport {
        println!("\n=== {title} ===");
        BenchReport { title: title.to_string(), ..Default::default() }
    }

    pub fn push(&mut self, r: BenchResult) {
        self.results.push(r);
    }

    pub fn payload(&mut self, text: String) {
        println!("{text}");
        self.payload.push(text);
    }

    /// Record an A/B ablation outcome as a payload line: the speedup of
    /// `new` over `base` (mean seconds per iteration) plus the acceptance
    /// criterion it targets. Returns the speedup so callers can branch on
    /// it. Used by the backend and warm-vs-cold session ablations.
    pub fn ablation(
        &mut self,
        label: &str,
        base_mean_s: f64,
        new_mean_s: f64,
        acceptance: &str,
    ) -> f64 {
        let speedup = base_mean_s / new_mean_s;
        self.payload(format!("{label}: speedup {speedup:.2}x ({acceptance})"));
        speedup
    }

    /// Render the timing summary table.
    pub fn summary_table(&self) -> String {
        let mut t = Table::new(&["bench", "mean ms", "median ms", "rsd %", "metric"])
            .title(&self.title)
            .left_first_col();
        for r in &self.results {
            t.row(vec![
                r.name.clone(),
                format!("{:.3}", r.summary.mean * 1e3),
                format!("{:.3}", r.summary.median * 1e3),
                format!("{:.1}", r.summary.rsd() * 100.0),
                r.metric.map(|(v, u)| format!("{v:.2} {u}")).unwrap_or_default(),
            ]);
        }
        t.render()
    }

    /// Machine-readable dump for EXPERIMENTS.md tooling.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::from(self.title.clone())),
            (
                "payload",
                Json::Arr(self.payload.iter().map(|p| Json::from(p.clone())).collect()),
            ),
            (
                "results",
                Json::Arr(
                    self.results
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("name", Json::from(r.name.clone())),
                                ("mean_s", Json::from(r.summary.mean)),
                                ("rsd", Json::from(r.summary.rsd())),
                                (
                                    "metric",
                                    r.metric
                                        .map(|(v, u)| {
                                            Json::obj(vec![
                                                ("value", Json::from(v)),
                                                ("unit", Json::from(u)),
                                            ])
                                        })
                                        .unwrap_or(Json::Null),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Print the footer (summary table); call at the end of main().
    pub fn finish(&self) {
        println!("\n{}", self.summary_table());
    }

    /// Persist the machine-readable dump ([`BenchReport::to_json`]) to
    /// `path`. `cargo bench` runs with the workspace root as cwd, so bench
    /// targets pass a bare `BENCH_*.json` name to land it at the repo root.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
    }

    /// [`BenchReport::finish`] plus [`BenchReport::write_json`], logging
    /// where the results went (write failures are reported, not fatal —
    /// benches may run from read-only checkouts).
    pub fn finish_json(&self, path: &str) {
        self.finish();
        match self.write_json(path) {
            Ok(()) => println!("wrote machine-readable results to {path}"),
            Err(e) => eprintln!("note: could not write {path}: {e}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bencher { warmup_iters: 1, sample_iters: 5, max_time: Duration::from_secs(5) };
        let mut acc = 0u64;
        let r = b.bench("spin", || {
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
        });
        assert!(acc > 0);
        assert!(r.summary.mean > 0.0);
        assert_eq!(r.summary.n, 5);
    }

    #[test]
    fn metric_is_throughput() {
        let b = Bencher { warmup_iters: 0, sample_iters: 3, max_time: Duration::from_secs(5) };
        let r = b.bench_with_metric("sleepless", "Kops/s", 1000.0, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        let (v, u) = r.metric.unwrap();
        assert!(v > 0.0);
        assert_eq!(u, "Kops/s");
    }

    #[test]
    fn report_roundtrip() {
        let mut rep = BenchReport::new("test report");
        let b = Bencher { warmup_iters: 0, sample_iters: 2, max_time: Duration::from_secs(1) };
        rep.push(b.bench("noop", || {}));
        let json = rep.to_json();
        assert_eq!(json.get("title").unwrap().as_str().unwrap(), "test report");
        assert!(rep.summary_table().contains("noop"));
    }

    #[test]
    fn ablation_records_speedup() {
        let mut rep = BenchReport::new("ablation test");
        let s = rep.ablation("warm-vs-cold", 2.0, 1.0, "acceptance: >= 1x");
        assert!((s - 2.0).abs() < 1e-12);
        assert!(rep.payload.iter().any(|p| p.contains("2.00x")));
    }

    #[test]
    fn json_dump_is_parseable_and_written() {
        let mut rep = BenchReport::new("persist test");
        let b = Bencher { warmup_iters: 0, sample_iters: 2, max_time: Duration::from_secs(1) };
        rep.push(b.bench_with_metric("unit", "ops/s", 1.0, || {}));
        let path = std::env::temp_dir().join("fstencil_bench_persist_test.json");
        let path = path.to_str().unwrap().to_string();
        rep.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(parsed.get("title").unwrap().as_str().unwrap(), "persist test");
        let results = parsed.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert!(results[0].get("metric").unwrap().get("value").unwrap().as_f64().unwrap() > 0.0);
        let _ = std::fs::remove_file(&path);
    }
}
