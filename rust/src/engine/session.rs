//! Warm, reusable single-tenant sessions — a thin facade over a private
//! one-tenant [`EngineServer`].
//!
//! The paper's accelerator is configured once and then fed a stream of
//! kernel invocations with runtime arguments (§3.2: coefficient changes
//! need no recompilation, remainder iterations ride on pass-through PEs).
//! A [`Session`] is the host analogue of that programmed device. Since the
//! multi-tenant server landed there is exactly ONE execution path: a
//! `Session` owns a private [`EngineServer`] with a single
//! [`super::ClientSession`] tenant, so the worker pool, the recirculating
//! tile-buffer pool and the role-alternating grid pair are the server's —
//! batched workloads pay the setup cost once, and the single- and
//! multi-tenant paths cannot drift apart.
//!
//! Reuse is observable, not aspirational: [`Session::threads_spawned`]
//! (one pool, at construction) and [`Session::fresh_tile_allocs`] (pool
//! misses — stops growing once the pool is warm, bounded by
//! [`Session::tile_pool_capacity`] forever) are test-visible counters
//! asserted by `rust/tests/engine_api.rs`.
//!
//! Submission semantics match the original session: `submit` completes
//! the job before the handle is returned (the scheduling happens on the
//! server's threads, but the facade waits), so errors are already
//! resolved on the handle. Callers that want true asynchrony and
//! multi-client fairness should open an [`EngineServer`] directly.

use crate::coordinator::{ExecReport, Plan};
use crate::stencil::Grid;

use super::{Backend, ClientSession, EngineError, EngineServer, JobHandle, Workload};

/// A warm execution context for one [`Plan`]: a private one-tenant
/// [`EngineServer`] whose persistent compute workers, recirculating
/// tile-buffer pool and grid double-buffer are reused by every
/// [`Session::submit`]. Create via [`super::StencilEngine::session`].
pub struct Session {
    server: EngineServer,
    client: ClientSession,
    submissions: u64,
}

impl Session {
    /// Build a session for `plan`, spawning its (private) server pool.
    /// `workers` overrides the plan's worker cap (`None` = plan's, which
    /// itself defaults to one worker per available core).
    pub(crate) fn spawn(plan: Plan, workers: Option<usize>) -> Result<Session, EngineError> {
        // Fail before any thread exists: an invalid backend must not
        // spawn (and immediately join) a whole worker pool.
        plan.backend.validate()?;
        let workers = workers
            .or(plan.workers)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2)
            })
            .max(1);
        let server = EngineServer::start(workers);
        let client = server.open(plan)?;
        Ok(Session { server, client, submissions: 0 })
    }

    pub fn plan(&self) -> &Plan {
        self.client.plan()
    }

    pub fn backend(&self) -> Backend {
        self.client.backend()
    }

    /// Size of the persistent compute pool.
    pub fn worker_threads(&self) -> usize {
        self.server.worker_threads()
    }

    /// Compute threads spawned over the session's lifetime — equals
    /// [`Session::worker_threads`] forever: one pool, spawned at
    /// construction, reused by every submission.
    pub fn threads_spawned(&self) -> u64 {
        self.server.threads_spawned()
    }

    /// Fresh tile-buffer allocations (pool misses) so far. Grows while
    /// the pool warms up, then plateaus: bounded by
    /// [`Session::tile_pool_capacity`] forever, however many jobs run.
    pub fn fresh_tile_allocs(&self) -> u64 {
        self.server.fresh_tile_allocs()
    }

    /// Total tile buffers the recirculating pool can ever hold. Buffers
    /// are never dropped on return, so [`Session::fresh_tile_allocs`] can
    /// never exceed this — the invariant the reuse tests assert.
    pub fn tile_pool_capacity(&self) -> usize {
        self.server.tile_pool_capacity()
    }

    /// Jobs submitted so far (including failed ones).
    pub fn submissions(&self) -> u64 {
        self.submissions
    }

    /// Submit one workload on the warm pool and wait for it to finish.
    /// Validation and execution errors both surface on the returned
    /// handle's [`JobHandle::wait`].
    pub fn submit<W: Into<Workload>>(&mut self, workload: W) -> JobHandle {
        self.submissions += 1;
        match self.client.submit(workload) {
            Ok(handle) => {
                handle.wait_done();
                handle
            }
            Err(e) => JobHandle::failed(e),
        }
    }

    /// Submit several workloads back-to-back on the warm pool.
    pub fn submit_batch<I>(&mut self, workloads: I) -> Vec<JobHandle>
    where
        I: IntoIterator,
        I::Item: Into<Workload>,
    {
        workloads.into_iter().map(|w| self.submit(w)).collect()
    }

    /// In-place convenience wrapper over [`Session::submit`]: updates
    /// `grid` and returns the report. Used by the CLI and the legacy
    /// `run_planned` entry points. On error the grid's contents are
    /// unspecified (a placeholder), matching the pipelines' long-standing
    /// error contract — callers that want the input preserved should go
    /// through [`Session::submit`], which only consumes the grid it is
    /// given.
    pub fn run(
        &mut self,
        grid: &mut Grid,
        power: Option<&Grid>,
    ) -> Result<ExecReport, EngineError> {
        let owned = std::mem::replace(grid, Grid::new2d(1, 1));
        let mut workload = Workload::new(owned);
        if let Some(p) = power {
            workload = workload.power(p.clone());
        }
        match self.submit(workload).wait() {
            Ok(out) => {
                *grid = out.grid;
                Ok(out.report)
            }
            Err(e) => Err(e),
        }
    }
}
