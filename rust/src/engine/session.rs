//! Warm, reusable execution sessions.
//!
//! The paper's accelerator is configured once and then fed a stream of
//! kernel invocations with runtime arguments (§3.2: coefficient changes
//! need no recompilation, remainder iterations ride on pass-through PEs).
//! A [`Session`] is the host analogue of that programmed device: it owns
//! the worker-thread pool, the recirculating tile buffers and the
//! role-alternating grid pair, and every [`Session::submit`] reuses them —
//! batched workloads pay the setup cost once instead of per run.
//!
//! Reuse is observable, not aspirational: [`Session::worker_threads`]
//! (spawned once, at construction) and [`Session::fresh_tile_allocs`]
//! (pool misses — stops growing once the pool is warm) are test-visible
//! counters asserted by `rust/tests/engine_api.rs`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::blocking::geometry::{Block, BlockGeometry};
use crate::coordinator::{ExecReport, Plan, StageTimes};
use crate::runtime::{extract_tile, writeback_tile, Executor, TileSpec};
use crate::stencil::Grid;

use super::{Backend, EngineError};

/// Channel depth between the compute pool and the write kernel — the
/// paper's inter-kernel channels are shallow; a small constant bounds
/// memory while hiding stage jitter.
const CHANNEL_DEPTH: usize = 4;

/// One computed tile flowing from a worker to the write kernel: block
/// index plus the result buffer (or the executor's error).
type TileResult = (usize, Result<Vec<f32>, anyhow::Error>);

/// One unit of work for a session: a grid, its optional power input, and
/// an optional iteration-count override (the plan's count when `None`).
/// `Grid` converts into a `Workload` directly, so `session.submit(grid)`
/// works for the common case.
#[derive(Debug)]
pub struct Workload {
    grid: Grid,
    power: Option<Grid>,
    iterations: Option<usize>,
}

impl Workload {
    pub fn new(grid: Grid) -> Workload {
        Workload { grid, power: None, iterations: None }
    }

    /// Attach a power grid (required for hotspot stencils).
    pub fn power(mut self, power: Grid) -> Workload {
        self.power = Some(power);
        self
    }

    /// Override the plan's iteration count for this job only. The session
    /// reschedules chunks with the plan's step-size set and reuses cached
    /// tile geometry per distinct chunk depth.
    pub fn iterations(mut self, iterations: usize) -> Workload {
        self.iterations = Some(iterations);
        self
    }
}

impl From<Grid> for Workload {
    fn from(grid: Grid) -> Workload {
        Workload::new(grid)
    }
}

/// A completed job: the updated grid and its execution report.
#[derive(Debug)]
pub struct JobOutput {
    pub grid: Grid,
    pub report: ExecReport,
}

/// Handle to a submitted job. Submission currently completes before the
/// handle is returned (the write kernel runs on the submitting thread, as
/// in the pipelines); the handle shape keeps the API stable for future
/// async serving. Errors surface at [`JobHandle::wait`].
#[derive(Debug)]
pub struct JobHandle {
    id: u64,
    result: Result<JobOutput, EngineError>,
}

impl JobHandle {
    /// Monotonically increasing per-session job id.
    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn is_ok(&self) -> bool {
        self.result.is_ok()
    }

    /// The job's report, if it succeeded.
    pub fn report(&self) -> Option<&ExecReport> {
        self.result.as_ref().ok().map(|o| &o.report)
    }

    /// Consume the handle, yielding the output grid and report.
    pub fn wait(self) -> Result<JobOutput, EngineError> {
        self.result
    }
}

/// State shared between the submitting thread and the worker pool.
struct Shared {
    tile: Vec<usize>,
    coeffs: Vec<f32>,
    exec: Box<dyn Executor + Send + Sync>,
    /// One `(spec, blocks)` per distinct chunk depth seen so far; grows
    /// when a submission's iteration override needs a new depth.
    specs: RwLock<Vec<(TileSpec, Vec<Block>)>>,
    /// The role-alternating grid pair: chunk `ci` reads `bufs[ci % 2]`
    /// and writes `bufs[(ci + 1) % 2]`. Allocated once per session.
    bufs: [RwLock<Grid>; 2],
    /// Power grid staged per submission (moved in, not copied).
    power: RwLock<Option<Grid>>,
    /// Per-submission stage-time accumulators (nanoseconds, summed
    /// across workers; reset by each submit).
    extract_ns: AtomicU64,
    compute_ns: AtomicU64,
    /// Fresh tile-buffer allocations: incremented when a worker's pool
    /// channel is empty and a new buffer must be created. Warm sessions
    /// stop incrementing this after the first submission.
    pool_misses: AtomicU64,
}

/// A warm execution context for one [`Plan`]: persistent compute workers,
/// recirculating tile-buffer pools and a persistent grid double buffer.
/// Create via [`super::StencilEngine::session`]; submit jobs with
/// [`Session::submit`] / [`Session::submit_batch`].
pub struct Session {
    plan: Plan,
    workers: usize,
    shared: Arc<Shared>,
    job_txs: Vec<SyncSender<(usize, usize)>>,
    pool_txs: Vec<SyncSender<Vec<f32>>>,
    rx_out: Option<Receiver<TileResult>>,
    handles: Vec<JoinHandle<()>>,
    threads_spawned: u64,
    submissions: u64,
    next_job_id: u64,
    /// Set when the worker pool died mid-protocol; all later submissions
    /// fail fast with [`EngineError::WorkerLost`].
    poisoned: bool,
}

impl Session {
    /// Build a session for `plan`, spawning its worker pool. `workers`
    /// overrides the plan's worker cap (`None` = plan's, which itself
    /// defaults to one worker per available core).
    pub(crate) fn spawn(plan: Plan, workers: Option<usize>) -> Result<Session, EngineError> {
        plan.backend.validate()?;
        let workers = workers
            .or(plan.workers)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2)
            })
            .max(1);
        let exec = plan.backend.executor();

        let cells: usize = plan.grid_dims.iter().product();
        let zero = Grid::from_vec(&plan.grid_dims, vec![0.0; cells]);
        let shared = Arc::new(Shared {
            tile: plan.tile.clone(),
            coeffs: plan.coeffs.clone(),
            exec,
            specs: RwLock::new(Vec::new()),
            bufs: [RwLock::new(zero.clone()), RwLock::new(zero)],
            power: RwLock::new(None),
            extract_ns: AtomicU64::new(0),
            compute_ns: AtomicU64::new(0),
            pool_misses: AtomicU64::new(0),
        });

        // Per-worker job and buffer-pool channels, one shared result
        // channel. Pool capacity covers the whole result channel so warm
        // buffers are never dropped on return (the reuse counter relies
        // on this).
        let (job_txs, job_rxs): (Vec<_>, Vec<_>) =
            (0..workers).map(|_| sync_channel::<(usize, usize)>(1)).unzip();
        let (pool_txs, pool_rxs): (Vec<_>, Vec<_>) = (0..workers)
            .map(|_| sync_channel::<Vec<f32>>(CHANNEL_DEPTH * workers + 2))
            .unzip();
        let (tx_out, rx_out) = sync_channel::<TileResult>(CHANNEL_DEPTH * workers);

        let mut handles = Vec::with_capacity(workers);
        for (w, (rx_job, pool_rx)) in job_rxs.into_iter().zip(pool_rxs).enumerate() {
            let shared = Arc::clone(&shared);
            let tx_out = tx_out.clone();
            // Each worker holds a sender to its OWN pool so buffers of
            // errored tiles recirculate instead of leaking — this keeps
            // fresh_tile_allocs <= tile_pool_capacity even across
            // executor failures.
            let pool_tx = pool_txs[w].clone();
            handles.push(std::thread::spawn(move || {
                worker_loop(&shared, w, workers, rx_job, pool_rx, pool_tx, tx_out)
            }));
        }

        let session = Session {
            plan,
            workers,
            shared,
            job_txs,
            pool_txs,
            rx_out: Some(rx_out),
            handles,
            threads_spawned: workers as u64,
            submissions: 0,
            next_job_id: 0,
            poisoned: false,
        };
        // Pre-build (and support-check) geometry for every chunk depth the
        // plan's schedule uses; iteration overrides grow the same cache
        // through the same path. On error the half-built session drops,
        // which joins the just-spawned pool cleanly.
        for &steps in &session.plan.chunks {
            session.ensure_spec(steps)?;
        }
        Ok(session)
    }

    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    pub fn backend(&self) -> Backend {
        self.plan.backend
    }

    /// Size of the persistent compute pool.
    pub fn worker_threads(&self) -> usize {
        self.workers
    }

    /// Worker threads spawned over the session's lifetime — equals
    /// [`Session::worker_threads`] forever: threads are spawned once at
    /// construction and reused by every submission.
    pub fn threads_spawned(&self) -> u64 {
        self.threads_spawned
    }

    /// Fresh tile-buffer allocations (pool misses) so far. Grows while
    /// the pool warms up, then plateaus: bounded by
    /// [`Session::tile_pool_capacity`] forever, however many jobs run.
    pub fn fresh_tile_allocs(&self) -> u64 {
        self.shared.pool_misses.load(Ordering::Relaxed)
    }

    /// Total tile buffers the recirculation pools can hold. Buffers are
    /// never dropped on return (pool capacity covers the whole result
    /// channel), so [`Session::fresh_tile_allocs`] can never exceed this
    /// — the invariant the reuse tests assert.
    pub fn tile_pool_capacity(&self) -> usize {
        self.workers * (CHANNEL_DEPTH * self.workers + 2)
    }

    /// Jobs submitted so far (including failed ones).
    pub fn submissions(&self) -> u64 {
        self.submissions
    }

    /// Submit one workload. Reuses the session's threads, buffers and
    /// grid pair; errors surface on the returned handle.
    pub fn submit<W: Into<Workload>>(&mut self, workload: W) -> JobHandle {
        let id = self.next_job_id;
        self.next_job_id += 1;
        self.submissions += 1;
        let result = self.run_workload(workload.into());
        JobHandle { id, result }
    }

    /// Submit several workloads back-to-back on the warm pool.
    pub fn submit_batch<I>(&mut self, workloads: I) -> Vec<JobHandle>
    where
        I: IntoIterator,
        I::Item: Into<Workload>,
    {
        workloads.into_iter().map(|w| self.submit(w)).collect()
    }

    /// In-place convenience wrapper over [`Session::submit`]: updates
    /// `grid` and returns the report. Used by the CLI and the legacy
    /// `run_planned` entry points. On error the grid's contents are
    /// unspecified (a placeholder), matching the pipelines' long-standing
    /// error contract — callers that want the input preserved should go
    /// through [`Session::submit`], which only consumes the grid it is
    /// given.
    pub fn run(
        &mut self,
        grid: &mut Grid,
        power: Option<&Grid>,
    ) -> Result<ExecReport, EngineError> {
        let owned = std::mem::replace(grid, Grid::new2d(1, 1));
        let mut workload = Workload::new(owned);
        if let Some(p) = power {
            workload = workload.power(p.clone());
        }
        match self.submit(workload).wait() {
            Ok(out) => {
                *grid = out.grid;
                Ok(out.report)
            }
            Err(e) => Err(e),
        }
    }

    /// Index of the cached `(spec, blocks)` entry for a chunk of `steps`,
    /// building (and support-checking) it on first use.
    fn ensure_spec(&self, steps: usize) -> Result<usize, EngineError> {
        if let Some(i) = self
            .shared
            .specs
            .read()
            .expect("spec cache poisoned")
            .iter()
            .position(|(sp, _)| sp.steps == steps)
        {
            return Ok(i);
        }
        let spec = self.plan.tile_spec(steps);
        if !self.shared.exec.supports(&spec) {
            return Err(EngineError::InvalidPlan(format!(
                "executor {} lacks tile program {}",
                self.shared.exec.backend_name(),
                spec.artifact_name()
            )));
        }
        let def = self.plan.stencil.def();
        let geom =
            BlockGeometry::tiled(&self.plan.grid_dims, &self.plan.tile, def.radius * steps);
        let mut specs = self.shared.specs.write().expect("spec cache poisoned");
        specs.push((spec, geom.blocks().collect()));
        Ok(specs.len() - 1)
    }

    fn run_workload(&mut self, workload: Workload) -> Result<JobOutput, EngineError> {
        if self.poisoned {
            return Err(EngineError::WorkerLost);
        }
        let Workload { mut grid, power, iterations } = workload;
        let plan = &self.plan;
        let def = plan.stencil.def();
        if grid.dims() != plan.grid_dims {
            return Err(EngineError::GridShape {
                expected: plan.grid_dims.clone(),
                got: grid.dims(),
            });
        }
        if power.is_some() != def.has_power {
            return Err(EngineError::PowerMismatch {
                expected: def.has_power,
                got: power.is_some(),
            });
        }
        if let Some(p) = &power {
            if p.dims() != plan.grid_dims {
                return Err(EngineError::PowerMismatch { expected: true, got: true });
            }
        }
        let iterations = iterations.unwrap_or(plan.iterations);
        let chunks = if iterations == plan.iterations {
            plan.chunks.clone()
        } else {
            plan.schedule_for(iterations)
                .map_err(|e| EngineError::InvalidPlan(format!("{e:#}")))?
        };
        let schedule = chunks
            .iter()
            .map(|&s| self.ensure_spec(s))
            .collect::<Result<Vec<_>, _>>()?;

        // Stage the job: move the power grid into the shared slot, copy
        // the input into the pass-0 read buffer (allocated once, reused).
        *self.shared.power.write().expect("power slot poisoned") = power;
        self.shared.bufs[0]
            .write()
            .expect("grid pair poisoned")
            .data_mut()
            .copy_from_slice(grid.data());
        self.shared.extract_ns.store(0, Ordering::Relaxed);
        self.shared.compute_ns.store(0, Ordering::Relaxed);

        let start = Instant::now();
        let mut tiles_executed = 0u64;
        let mut redundant = 0u64;
        let mut write_time = Duration::ZERO;
        let mut run_err: Option<EngineError> = None;
        let mut pool_lost = false;
        let rx_out = self.rx_out.as_ref().expect("session result channel gone");

        'chunks: for (ci, &spec_i) in schedule.iter().enumerate() {
            let src = ci % 2;
            let dst = (ci + 1) % 2;
            for tx in &self.job_txs {
                if tx.send((spec_i, src)).is_err() {
                    run_err = Some(EngineError::WorkerLost);
                    pool_lost = true;
                    break 'chunks;
                }
            }
            let specs = self.shared.specs.read().expect("spec cache poisoned");
            let (spec, blocks) = &specs[spec_i];
            let mut next = self.shared.bufs[dst].write().expect("grid pair poisoned");
            // Drain every tile of the chunk even after an error so the
            // channel protocol stays clean and the session survives.
            for _ in 0..blocks.len() {
                match rx_out.recv() {
                    Ok((i, Ok(out))) => {
                        let t0 = Instant::now();
                        writeback_tile(&mut next, &blocks[i], &self.shared.tile, &out);
                        write_time += t0.elapsed();
                        tiles_executed += 1;
                        let useful: usize =
                            blocks[i].compute.iter().map(|(lo, hi)| hi - lo).product();
                        redundant += (spec.cells() - useful) as u64 * spec.steps as u64;
                        let _ = self.pool_txs[i % self.workers].try_send(out);
                    }
                    Ok((_, Err(e))) => {
                        if run_err.is_none() {
                            run_err = Some(EngineError::from(e));
                        }
                    }
                    Err(_) => {
                        run_err = Some(EngineError::WorkerLost);
                        pool_lost = true;
                        break 'chunks;
                    }
                }
            }
            if run_err.is_some() {
                break;
            }
        }
        if pool_lost {
            self.poisoned = true;
        }
        if let Some(e) = run_err {
            return Err(e);
        }

        grid.data_mut().copy_from_slice(
            self.shared.bufs[schedule.len() % 2]
                .read()
                .expect("grid pair poisoned")
                .data(),
        );
        let ns = |a: &AtomicU64| Duration::from_nanos(a.load(Ordering::Relaxed));
        let cell_updates =
            self.plan.grid_dims.iter().product::<usize>() as u64 * iterations as u64;
        Ok(JobOutput {
            grid,
            report: ExecReport {
                iterations,
                passes: schedule.len(),
                tiles_executed,
                cell_updates,
                redundant_updates: redundant,
                elapsed: start.elapsed(),
                backend: self.plan.backend.session_label(),
                stages: Some(StageTimes {
                    extract: ns(&self.shared.extract_ns),
                    compute: ns(&self.shared.compute_ns),
                    write: write_time,
                }),
            },
        })
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        // Unblock workers stuck sending (aborted submission), then close
        // the job channels so idle workers exit, then reap.
        self.rx_out.take();
        self.job_txs.clear();
        self.pool_txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Compute-worker body: blocks are sharded statically (block `i` → worker
/// `i % workers`); each worker extracts its own tiles, reuses pooled
/// result buffers, and stays alive across submissions until the session
/// drops its job channel. Executor errors are forwarded per-tile (the
/// worker keeps serving its remaining blocks so the drain stays exact).
fn worker_loop(
    shared: &Shared,
    w: usize,
    workers: usize,
    rx_job: Receiver<(usize, usize)>,
    pool_rx: Receiver<Vec<f32>>,
    pool_tx: SyncSender<Vec<f32>>,
    tx_out: SyncSender<TileResult>,
) {
    let mut tile = Vec::new();
    let mut ptile = Vec::new();
    while let Ok((spec_i, src)) = rx_job.recv() {
        let specs = shared.specs.read().expect("spec cache poisoned");
        let (spec, blocks) = &specs[spec_i];
        let cur = shared.bufs[src].read().expect("grid pair poisoned");
        let power = shared.power.read().expect("power slot poisoned");
        for (i, b) in blocks.iter().enumerate().skip(w).step_by(workers) {
            let t0 = Instant::now();
            extract_tile(&cur, b, &shared.tile, &mut tile);
            let pw = power.as_ref().map(|pg| {
                extract_tile(pg, b, &shared.tile, &mut ptile);
                ptile.as_slice()
            });
            let t1 = Instant::now();
            let mut out = match pool_rx.try_recv() {
                Ok(buf) => buf,
                Err(_) => {
                    shared.pool_misses.fetch_add(1, Ordering::Relaxed);
                    Vec::new()
                }
            };
            let res = shared.exec.run_tile_into(spec, &tile, pw, &shared.coeffs, &mut out);
            shared
                .extract_ns
                .fetch_add((t1 - t0).as_nanos() as u64, Ordering::Relaxed);
            shared
                .compute_ns
                .fetch_add(t1.elapsed().as_nanos() as u64, Ordering::Relaxed);
            let msg = match res {
                Ok(()) => (i, Ok(out)),
                Err(e) => {
                    // Recirculate the buffer of a failed tile so errors
                    // never shrink the pool.
                    let _ = pool_tx.try_send(out);
                    (i, Err(e))
                }
            };
            if tx_out.send(msg).is_err() {
                return; // session is tearing down
            }
        }
    }
}
