//! Seeded, deterministic fault injection for the fault-tolerance layer.
//!
//! A [`ChaosPlan`] is a reproducible schedule of typed fault events: each
//! decision is a pure function of `(seed, fault kind, job, attempt, tile)`,
//! hashed splitmix64-style and compared against the kind's configured rate.
//! Re-running the same workload under the same plan injects *exactly* the
//! same faults, so every recovery path — retry, journal replay, checkpoint
//! resume, reconnect — is testable with bit-level assertions instead of
//! sleeps and luck. This replaces the old `WireConfig::fault_fail_attempts`
//! toy counter (PR 6), which could only fail the first N attempts of every
//! job identically.
//!
//! The plan is threaded through three layers:
//! - the **worker pool** ([`super::server`]): `exec` fails a tile, `slow`
//!   delays it (exercises drain paths without changing results);
//! - **[`super::wire::JobLedger`] IO**: `journal` drops an append, `short`
//!   writes half a record with no newline (a torn tail for replay to skip);
//! - the **wire frontend**: `ckpt` corrupts a checkpoint sidecar as it is
//!   written, `drop` severs a connection after a response frame;
//! - the **cluster coordinator** ([`crate::cluster`]): `kill` makes a
//!   shard worker die abruptly mid-sweep (process exit / socket teardown),
//!   exercising the worker-death → typed-failure path.
//!
//! CLI form: `serve --chaos '<seed>:<kind>=<rate>[@<max_attempt>],...'`,
//! e.g. `--chaos '42:exec=0.05,slow=0.1,drop=0.01'`. The optional `@N`
//! suffix stops injecting that kind once a job is past attempt `N`, which
//! is how the retry-recovery tests express "fail attempts 1..=N, then let
//! it land".

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// One class of injectable fault. `code()` is the spelling used in the
/// `--chaos` spec grammar.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// A tile execution fails with a retryable executor error.
    ExecFail,
    /// A tile is delayed a few milliseconds (reorders completions).
    SlowTile,
    /// A journal append is silently dropped (write failure).
    JournalFail,
    /// A journal append writes only half the record, no newline (torn tail).
    JournalShortWrite,
    /// A checkpoint sidecar is corrupted as it is written.
    CheckpointCorrupt,
    /// A wire connection is severed after answering a frame.
    ConnDrop,
    /// A cluster worker process dies abruptly mid-sweep (the shard's
    /// process exits / its socket is torn down without a goodbye).
    WorkerKill,
}

impl FaultKind {
    /// Every kind, in spec-grammar order.
    pub const ALL: [FaultKind; 7] = [
        FaultKind::ExecFail,
        FaultKind::SlowTile,
        FaultKind::JournalFail,
        FaultKind::JournalShortWrite,
        FaultKind::CheckpointCorrupt,
        FaultKind::ConnDrop,
        FaultKind::WorkerKill,
    ];

    /// The spec-grammar spelling.
    pub fn code(self) -> &'static str {
        match self {
            FaultKind::ExecFail => "exec",
            FaultKind::SlowTile => "slow",
            FaultKind::JournalFail => "journal",
            FaultKind::JournalShortWrite => "short",
            FaultKind::CheckpointCorrupt => "ckpt",
            FaultKind::ConnDrop => "drop",
            FaultKind::WorkerKill => "kill",
        }
    }

    /// Inverse of [`FaultKind::code`].
    pub fn parse(s: &str) -> Option<FaultKind> {
        FaultKind::ALL.iter().copied().find(|k| k.code() == s)
    }

    /// Per-kind salt so the same `(job, attempt, tile)` key draws an
    /// independent decision for each fault class.
    fn salt(self) -> u64 {
        match self {
            FaultKind::ExecFail => 0xE4EC_0001_9E37_79B9,
            FaultKind::SlowTile => 0x510E_0002_9E37_79B9,
            FaultKind::JournalFail => 0x10BA_0003_9E37_79B9,
            FaultKind::JournalShortWrite => 0x5087_0004_9E37_79B9,
            FaultKind::CheckpointCorrupt => 0xCC97_0005_9E37_79B9,
            FaultKind::ConnDrop => 0xD809_0006_9E37_79B9,
            FaultKind::WorkerKill => 0x3177_0007_9E37_79B9,
        }
    }

    fn index(self) -> usize {
        FaultKind::ALL.iter().position(|k| *k == self).expect("kind in ALL")
    }
}

/// One `<kind>=<rate>[@<max_attempt>]` clause.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Rule {
    kind: FaultKind,
    /// Injection probability in `[0, 1]`; `1` injects unconditionally.
    rate: f64,
    /// Only inject while `attempt <= max_attempt`; `0` = no cap.
    max_attempt: u32,
}

/// A seeded, deterministic fault-injection schedule.
///
/// `should()` is the single decision point: pure in its arguments (plus
/// the seed), so a schedule replays identically across process restarts —
/// the crash-resume soak in `wire_faults.rs` depends on that.
#[derive(Debug)]
pub struct ChaosPlan {
    seed: u64,
    rules: Vec<Rule>,
    /// Injection counters per kind (observability: health check, logs).
    injected: [AtomicU64; 7],
}

/// splitmix64 finalizer: a cheap, well-mixed avalanche.
fn mix(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    x
}

impl ChaosPlan {
    /// An empty (never-injecting) plan with the given seed.
    pub fn new(seed: u64) -> ChaosPlan {
        ChaosPlan { seed, rules: Vec::new(), injected: [(); 7].map(|()| AtomicU64::new(0)) }
    }

    /// Add or replace the rule for `kind`. `max_attempt == 0` means no
    /// attempt cap. Builder-style, mostly for tests; the CLI goes through
    /// [`ChaosPlan::parse`].
    pub fn rule(mut self, kind: FaultKind, rate: f64, max_attempt: u32) -> ChaosPlan {
        self.rules.retain(|r| r.kind != kind);
        self.rules.push(Rule { kind, rate: rate.clamp(0.0, 1.0), max_attempt });
        self
    }

    /// Parse `"<seed>:<kind>=<rate>[@<max_attempt>],..."`, e.g.
    /// `"42:exec=0.05,slow=0.1"` or `"7:exec=1@2"` (fail every tile of
    /// attempts 1 and 2, then stop).
    pub fn parse(spec: &str) -> Result<ChaosPlan, String> {
        let (seed_s, rest) = spec
            .split_once(':')
            .ok_or_else(|| format!("chaos spec {spec:?}: expected '<seed>:<kind>=<rate>,...'"))?;
        let seed: u64 = seed_s
            .trim()
            .parse()
            .map_err(|_| format!("chaos spec {spec:?}: bad seed {seed_s:?}"))?;
        let mut plan = ChaosPlan::new(seed);
        for clause in rest.split(',') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let (kind_s, rate_s) = clause
                .split_once('=')
                .ok_or_else(|| format!("chaos clause {clause:?}: expected '<kind>=<rate>'"))?;
            let kind = FaultKind::parse(kind_s.trim()).ok_or_else(|| {
                format!(
                    "chaos clause {clause:?}: unknown kind {:?} (expected one of {})",
                    kind_s.trim(),
                    FaultKind::ALL.map(FaultKind::code).join("/")
                )
            })?;
            let (rate_s, max_attempt) = match rate_s.split_once('@') {
                Some((r, a)) => (
                    r,
                    a.trim()
                        .parse::<u32>()
                        .map_err(|_| format!("chaos clause {clause:?}: bad attempt cap {a:?}"))?,
                ),
                None => (rate_s, 0),
            };
            let rate: f64 = rate_s
                .trim()
                .parse()
                .map_err(|_| format!("chaos clause {clause:?}: bad rate {rate_s:?}"))?;
            if !(0.0..=1.0).contains(&rate) {
                return Err(format!("chaos clause {clause:?}: rate must be in [0, 1]"));
            }
            plan = plan.rule(kind, rate, max_attempt);
        }
        Ok(plan)
    }

    /// True if any rule can inject (drives the health check's chaos flag).
    pub fn active(&self) -> bool {
        self.rules.iter().any(|r| r.rate > 0.0)
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The deterministic decision: should `kind` fire for this
    /// `(job, attempt, tile)` key? Pure in its arguments — the same key
    /// under the same plan always answers the same — except for the
    /// injection counter bump on a hit.
    pub fn should(&self, kind: FaultKind, job: u64, attempt: u32, tile: u64) -> bool {
        let Some(rule) = self.rules.iter().find(|r| r.kind == kind) else {
            return false;
        };
        if rule.rate <= 0.0 || (rule.max_attempt > 0 && attempt > rule.max_attempt) {
            return false;
        }
        let hit = if rule.rate >= 1.0 {
            true
        } else {
            let h = mix(
                mix(self.seed ^ kind.salt())
                    ^ mix(job.wrapping_mul(0x9E37_79B9_7F4A_7C15))
                    ^ mix(((attempt as u64) << 40) ^ tile),
            );
            let unit = (h >> 11) as f64 / (1u64 << 53) as f64;
            unit < rule.rate
        };
        if hit {
            self.injected[kind.index()].fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// How many times `kind` has fired so far.
    pub fn injected(&self, kind: FaultKind) -> u64 {
        self.injected[kind.index()].load(Ordering::Relaxed)
    }

    /// Total injections across all kinds.
    pub fn total_injected(&self) -> u64 {
        FaultKind::ALL.iter().map(|k| self.injected(*k)).sum()
    }
}

impl fmt::Display for ChaosPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:", self.seed)?;
        for (i, r) in self.rules.iter().enumerate() {
            if i > 0 {
                f.write_str(",")?;
            }
            write!(f, "{}={}", r.kind.code(), r.rate)?;
            if r.max_attempt > 0 {
                write!(f, "@{}", r.max_attempt)?;
            }
        }
        Ok(())
    }
}

/// Per-job chaos context carried into the worker pool: the plan plus the
/// `(job, attempt)` half of the decision key (the tile half is supplied by
/// the worker at dispatch). Attached via
/// [`super::server::Workload::chaos`].
#[derive(Debug, Clone)]
pub struct ChaosCtx {
    pub plan: Arc<ChaosPlan>,
    /// Stable job key — the wire layer uses the ledger job id.
    pub job: u64,
    /// 1-based attempt number.
    pub attempt: u32,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_grammar_round_trips() {
        let plan = ChaosPlan::parse("42:exec=0.05,slow=0.1,drop=1@3").unwrap();
        assert_eq!(plan.seed(), 42);
        assert!(plan.active());
        let reparsed = ChaosPlan::parse(&plan.to_string()).unwrap();
        assert_eq!(plan.to_string(), reparsed.to_string());
        assert_eq!(plan.to_string(), "42:exec=0.05,slow=0.1,drop=1@3");
    }

    #[test]
    fn bad_specs_are_rejected_with_context() {
        for bad in ["", "noseed", "x:exec=1", "1:bogus=1", "1:exec=2", "1:exec=0.5@x"] {
            let err = ChaosPlan::parse(bad).unwrap_err();
            assert!(err.contains("chaos"), "{bad:?} -> {err}");
        }
    }

    #[test]
    fn decisions_are_deterministic_and_rate_shaped() {
        let a = ChaosPlan::new(7).rule(FaultKind::ExecFail, 0.5, 0);
        let b = ChaosPlan::new(7).rule(FaultKind::ExecFail, 0.5, 0);
        let mut hits = 0;
        for job in 0..10u64 {
            for tile in 0..100u64 {
                let x = a.should(FaultKind::ExecFail, job, 1, tile);
                assert_eq!(x, b.should(FaultKind::ExecFail, job, 1, tile));
                hits += x as usize;
            }
        }
        // 1000 Bernoulli(0.5) draws: far outside [350, 650] means the hash
        // is broken, not unlucky.
        assert!((350..=650).contains(&hits), "rate 0.5 produced {hits}/1000 hits");
        // A different seed must disagree somewhere.
        let c = ChaosPlan::new(8).rule(FaultKind::ExecFail, 0.5, 0);
        let diverges = (0..100u64).any(|t| {
            a.should(FaultKind::ExecFail, 0, 1, t) != c.should(FaultKind::ExecFail, 0, 1, t)
        });
        assert!(diverges, "seeds 7 and 8 produced identical schedules");
    }

    #[test]
    fn rate_edges_and_attempt_caps() {
        let always = ChaosPlan::new(1).rule(FaultKind::ExecFail, 1.0, 2);
        for tile in 0..32 {
            assert!(always.should(FaultKind::ExecFail, 9, 1, tile));
            assert!(always.should(FaultKind::ExecFail, 9, 2, tile));
            assert!(!always.should(FaultKind::ExecFail, 9, 3, tile), "capped at attempt 2");
        }
        let never = ChaosPlan::new(1).rule(FaultKind::SlowTile, 0.0, 0);
        assert!((0..32).all(|t| !never.should(FaultKind::SlowTile, 9, 1, t)));
        assert!(!never.active());
        // Unconfigured kinds never fire.
        assert!(!always.should(FaultKind::ConnDrop, 9, 1, 0));
        assert_eq!(always.injected(FaultKind::ExecFail), 64);
        assert_eq!(always.total_injected(), 64);
    }

    #[test]
    fn worker_kill_kind_parses_and_draws_independently() {
        let plan = ChaosPlan::parse("11:kill=1@1").unwrap();
        assert!(plan.active());
        assert!(plan.should(FaultKind::WorkerKill, 1, 1, 0));
        assert!(!plan.should(FaultKind::WorkerKill, 1, 2, 0), "capped at attempt 1");
        assert_eq!(plan.to_string(), "11:kill=1@1");
        // kill draws from its own salt, not drop's.
        let both =
            ChaosPlan::new(5).rule(FaultKind::ConnDrop, 0.5, 0).rule(FaultKind::WorkerKill, 0.5, 0);
        let diverges = (0..200u64).any(|t| {
            both.should(FaultKind::ConnDrop, 1, 1, t) != both.should(FaultKind::WorkerKill, 1, 1, t)
        });
        assert!(diverges, "drop and kill schedules are identical — salts broken");
    }

    #[test]
    fn kinds_draw_independent_decisions() {
        let plan =
            ChaosPlan::new(3).rule(FaultKind::ExecFail, 0.5, 0).rule(FaultKind::SlowTile, 0.5, 0);
        let diverges = (0..200u64).any(|t| {
            plan.should(FaultKind::ExecFail, 1, 1, t) != plan.should(FaultKind::SlowTile, 1, 1, t)
        });
        assert!(diverges, "exec and slow schedules are identical — salts broken");
    }
}
