//! The engine layer: the crate's front door for executing plans.
//!
//! The paper's runtime model is *program once, invoke many times*: the
//! FPGA is configured with a (`par_vec`, `par_time`) design and then fed
//! a stream of kernel invocations whose coefficients and grids are
//! runtime arguments (§3.2). This module is the host-side reproduction
//! of that contract — and, since the multi-tenant server landed, of the
//! ROADMAP's serving ambition (one shared device, many concurrent
//! tenants):
//!
//! * [`Backend`] — the typed, single point of executor selection
//!   (scalar oracle / vectorized lanes / streaming shift-register).
//! * [`StencilEngine`] — the facade. [`StencilEngine::session`] turns a
//!   [`Plan`] into a warm single-tenant [`Session`];
//!   [`StencilEngine::serve`] starts a multi-tenant [`EngineServer`];
//!   [`StencilEngine::run`] is the one-shot convenience.
//! * [`EngineServer`] — ONE shared worker pool multiplexing many
//!   concurrent [`ClientSession`]s (any stencil × any backend mix) under
//!   deficit-round-robin tile scheduling, with bounded per-client queues
//!   (backpressure on submit), job cancellation and graceful shutdown.
//! * [`Session`] — the single-tenant facade over a private one-tenant
//!   server: same code path, warm worker threads, recirculating
//!   tile-buffer pool and grid double-buffer reused by every
//!   [`Session::submit`].
//! * [`EngineError`] — typed errors at the public boundary.
//! * [`wire`] — the TCP front door: [`wire::WireFrontend`] multiplexes
//!   network tenants onto an [`EngineServer`] (length-prefixed JSON
//!   frames, durable job ledger, retry and quotas); [`wire::WireClient`]
//!   is the typed blocking client.
//! * [`chaos`] — seeded deterministic fault injection ([`ChaosPlan`])
//!   threaded through the worker pool, the ledger's journal IO and the
//!   frontend, so every recovery path replays identically under test.
//!
//! ```no_run
//! use fstencil::prelude::*;
//!
//! let plan = PlanBuilder::new(StencilKind::Diffusion2D)
//!     .grid_dims(vec![256, 256])
//!     .iterations(8)
//!     .backend(Backend::Vec { par_vec: 8 })
//!     .build()?;
//! let mut session = StencilEngine::new().session(plan)?;
//! for seed in 0..4u64 {
//!     let mut grid = Grid::new2d(256, 256);
//!     grid.fill_random(seed, 0.0, 1.0);
//!     let out = session.submit(grid).wait()?;
//!     println!("job: {:.1} Mcell/s", out.report.mcells_per_sec());
//! }
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! Multi-tenant serving (several plans, one pool):
//!
//! ```no_run
//! use fstencil::prelude::*;
//!
//! let server = StencilEngine::new().serve(8); // 8 shared workers
//! let diffusion = server.open(
//!     PlanBuilder::new(StencilKind::Diffusion2D)
//!         .grid_dims(vec![512, 512])
//!         .iterations(16)
//!         .backend(Backend::Vec { par_vec: 8 })
//!         .build()?,
//! )?;
//! let hotspot = server.open(
//!     PlanBuilder::new(StencilKind::Hotspot2D)
//!         .grid_dims(vec![256, 256])
//!         .iterations(8)
//!         .build()?,
//! )?;
//! let mut g = Grid::new2d(512, 512);
//! g.fill_random(1, 0.0, 1.0);
//! let job = diffusion.submit(g)?; // async; DRR-fair against hotspot's jobs
//! let mut h = Grid::new2d(256, 256);
//! h.fill_random(2, 0.0, 1.0);
//! let mut p = Grid::new2d(256, 256);
//! p.fill_random(3, 0.0, 0.25);
//! let job2 = hotspot.submit(Workload::new(h).power(p))?;
//! let (a, b) = (job.wait()?, job2.wait()?);
//! println!("{} + {} tiles through one pool", a.report.tiles_executed, b.report.tiles_executed);
//! # Ok::<(), anyhow::Error>(())
//! ```

mod backend;
pub mod chaos;
mod error;
mod scheduler;
mod server;
mod session;
pub mod wire;

pub use backend::Backend;
pub use chaos::{ChaosCtx, ChaosPlan, FaultKind};
pub use error::EngineError;
pub use scheduler::DeficitRoundRobin;
pub use server::{
    CheckpointSink, ClientSession, ClientStats, EngineServer, JobHandle, JobOutput, Workload,
    DEFAULT_QUEUE_DEPTH, QUEUE_WAIT_BUCKETS,
};
pub use session::Session;

use crate::coordinator::{ExecReport, Plan};
use crate::stencil::Grid;

/// The engine facade: one place where sessions, servers and one-shot runs
/// are opened.
#[derive(Debug, Clone, Copy, Default)]
pub struct StencilEngine;

impl StencilEngine {
    pub fn new() -> StencilEngine {
        StencilEngine
    }

    /// Open a warm single-tenant [`Session`] for `plan`: spawns its
    /// worker pool once; every subsequent [`Session::submit`] reuses it.
    pub fn session(&self, plan: Plan) -> Result<Session, EngineError> {
        Session::spawn(plan, None)
    }

    /// [`StencilEngine::session`] with an explicit worker-pool size,
    /// overriding the plan's cap.
    pub fn session_with_workers(
        &self,
        plan: Plan,
        workers: usize,
    ) -> Result<Session, EngineError> {
        Session::spawn(plan, Some(workers.max(1)))
    }

    /// Start a multi-tenant [`EngineServer`] with `workers` shared
    /// compute threads; open tenants with [`EngineServer::open`].
    pub fn serve(&self, workers: usize) -> EngineServer {
        EngineServer::start(workers)
    }

    /// One-shot convenience: open a session, run `grid` through it
    /// in-place, tear it down. Batched callers should hold a [`Session`]
    /// instead and amortize the setup.
    pub fn run(
        &self,
        plan: Plan,
        grid: &mut Grid,
        power: Option<&Grid>,
    ) -> Result<ExecReport, EngineError> {
        self.session(plan)?.run(grid, power)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::PlanBuilder;
    use crate::stencil::StencilKind;

    #[test]
    fn one_shot_run_matches_plan() {
        let plan = PlanBuilder::new(StencilKind::Diffusion2D)
            .grid_dims(vec![64, 64])
            .iterations(3)
            .build()
            .unwrap();
        let mut grid = Grid::new2d(64, 64);
        grid.fill_random(5, 0.0, 1.0);
        let rep = StencilEngine::new().run(plan, &mut grid, None).unwrap();
        assert_eq!(rep.iterations, 3);
        assert_eq!(rep.backend, "session-scalar");
        assert!(rep.tiles_executed > 0);
    }

    #[test]
    fn session_rejects_wrong_grid_shape() {
        let plan = PlanBuilder::new(StencilKind::Diffusion2D)
            .grid_dims(vec![64, 64])
            .build()
            .unwrap();
        let mut session = StencilEngine::new().session(plan).unwrap();
        let err = session.submit(Grid::new2d(32, 32)).wait().unwrap_err();
        assert_eq!(
            err,
            EngineError::GridShape { expected: vec![64, 64], got: vec![32, 32] }
        );
        // the session survives a rejected job
        let mut ok = Grid::new2d(64, 64);
        ok.fill_random(1, 0.0, 1.0);
        assert!(session.submit(ok).is_ok());
    }

    #[test]
    fn session_rejects_power_mismatch() {
        let plan = PlanBuilder::new(StencilKind::Hotspot2D)
            .grid_dims(vec![64, 64])
            .build()
            .unwrap();
        let mut session = StencilEngine::new().session(plan).unwrap();
        let err = session.submit(Grid::new2d(64, 64)).wait().unwrap_err();
        assert_eq!(err, EngineError::PowerMismatch { expected: true, got: false });
    }
}
