//! The engine layer: the crate's front door for executing plans.
//!
//! The paper's runtime model is *program once, invoke many times*: the
//! FPGA is configured with a (`par_vec`, `par_time`) design and then fed
//! a stream of kernel invocations whose coefficients and grids are
//! runtime arguments (§3.2). This module is the host-side reproduction
//! of that contract:
//!
//! * [`Backend`] — the typed, single point of executor selection
//!   (scalar oracle / vectorized lanes / streaming shift-register),
//!   replacing the old implicit `stream: bool` + `par_vec > 1` pair.
//! * [`StencilEngine`] — the facade. [`StencilEngine::session`] turns a
//!   [`Plan`] into a warm [`Session`]; [`StencilEngine::run`] is the
//!   one-shot convenience.
//! * [`Session`] — persistent worker threads, recirculating tile-buffer
//!   pools and a role-alternating grid pair, reused by every
//!   [`Session::submit`] so batched workloads amortize setup.
//! * [`EngineError`] — typed errors at the public boundary.
//!
//! ```no_run
//! use fstencil::prelude::*;
//!
//! let plan = PlanBuilder::new(StencilKind::Diffusion2D)
//!     .grid_dims(vec![256, 256])
//!     .iterations(8)
//!     .backend(Backend::Vec { par_vec: 8 })
//!     .build()?;
//! let mut session = StencilEngine::new().session(plan)?;
//! for seed in 0..4u64 {
//!     let mut grid = Grid::new2d(256, 256);
//!     grid.fill_random(seed, 0.0, 1.0);
//!     let out = session.submit(grid).wait()?;
//!     println!("job: {:.1} Mcell/s", out.report.mcells_per_sec());
//! }
//! # Ok::<(), anyhow::Error>(())
//! ```

mod backend;
mod error;
mod session;

pub use backend::Backend;
pub use error::EngineError;
pub use session::{JobHandle, JobOutput, Session, Workload};

use crate::coordinator::{ExecReport, Plan};
use crate::stencil::Grid;

/// The engine facade. Stateless today (sessions own all warm state);
/// exists so serving-layer concerns — session routing, admission
/// control, sharding — have one place to land.
#[derive(Debug, Clone, Copy, Default)]
pub struct StencilEngine;

impl StencilEngine {
    pub fn new() -> StencilEngine {
        StencilEngine
    }

    /// Open a warm [`Session`] for `plan`: spawns the worker pool once;
    /// every subsequent [`Session::submit`] reuses it.
    pub fn session(&self, plan: Plan) -> Result<Session, EngineError> {
        Session::spawn(plan, None)
    }

    /// [`StencilEngine::session`] with an explicit worker-pool size,
    /// overriding the plan's cap.
    pub fn session_with_workers(
        &self,
        plan: Plan,
        workers: usize,
    ) -> Result<Session, EngineError> {
        Session::spawn(plan, Some(workers.max(1)))
    }

    /// One-shot convenience: open a session, run `grid` through it
    /// in-place, tear it down. Batched callers should hold a [`Session`]
    /// instead and amortize the setup.
    pub fn run(
        &self,
        plan: Plan,
        grid: &mut Grid,
        power: Option<&Grid>,
    ) -> Result<ExecReport, EngineError> {
        self.session(plan)?.run(grid, power)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::PlanBuilder;
    use crate::stencil::StencilKind;

    #[test]
    fn one_shot_run_matches_plan() {
        let plan = PlanBuilder::new(StencilKind::Diffusion2D)
            .grid_dims(vec![64, 64])
            .iterations(3)
            .build()
            .unwrap();
        let mut grid = Grid::new2d(64, 64);
        grid.fill_random(5, 0.0, 1.0);
        let rep = StencilEngine::new().run(plan, &mut grid, None).unwrap();
        assert_eq!(rep.iterations, 3);
        assert_eq!(rep.backend, "session-scalar");
        assert!(rep.tiles_executed > 0);
    }

    #[test]
    fn session_rejects_wrong_grid_shape() {
        let plan = PlanBuilder::new(StencilKind::Diffusion2D)
            .grid_dims(vec![64, 64])
            .build()
            .unwrap();
        let mut session = StencilEngine::new().session(plan).unwrap();
        let err = session.submit(Grid::new2d(32, 32)).wait().unwrap_err();
        assert_eq!(
            err,
            EngineError::GridShape { expected: vec![64, 64], got: vec![32, 32] }
        );
        // the session survives a rejected job
        let mut ok = Grid::new2d(64, 64);
        ok.fill_random(1, 0.0, 1.0);
        assert!(session.submit(ok).is_ok());
    }

    #[test]
    fn session_rejects_power_mismatch() {
        let plan = PlanBuilder::new(StencilKind::Hotspot2D)
            .grid_dims(vec![64, 64])
            .build()
            .unwrap();
        let mut session = StencilEngine::new().session(plan).unwrap();
        let err = session.submit(Grid::new2d(64, 64)).wait().unwrap_err();
        assert_eq!(err, EngineError::PowerMismatch { expected: true, got: false });
    }
}
