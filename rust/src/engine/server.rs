//! The multi-tenant engine server: many clients, one shared worker pool.
//!
//! The paper's accelerator keeps a single deeply pipelined PE chain busy
//! by streaming an unbounded sequence of blocks through it (§3.2, Fig. 2);
//! *whose* blocks flow next is purely a host-side scheduling decision. An
//! [`EngineServer`] is that device shared between tenants: one pool of
//! persistent compute workers and one recirculating tile-buffer pool serve
//! any number of concurrent [`ClientSession`]s, each opened from its own
//! [`Plan`] (any stencil × any backend). Clients enqueue [`Workload`]s
//! into bounded per-client queues — [`ClientSession::submit`] blocks when
//! the queue is full (backpressure) — and a deficit-round-robin scheduler
//! ([`super::DeficitRoundRobin`]) drains them at *tile-chunk* granularity,
//! so a huge 3-D job cannot starve small 2-D jobs.
//!
//! ## Structure
//!
//! * one **scheduler thread** owns all cross-client state behind a single
//!   event loop (submissions, tile completions, cancellations, shutdown);
//!   it stages jobs into each client's persistent grid double-buffer,
//!   dispatches tiles picked by DRR, performs write-backs and advances
//!   chunk barriers;
//! * `workers` **compute threads** block on one shared task queue, extract
//!   their tiles from the owning client's read buffer, run the client's
//!   executor, and send results back as events;
//! * tile buffers recirculate through one shared pool whose high-water
//!   mark is bounded by the dispatch window ([`EngineServer::tile_pool_capacity`]),
//!   so [`EngineServer::fresh_tile_allocs`] plateaus once the pool is
//!   warm, however many clients and jobs run.
//!
//! Lock order is strictly `state → (specs | bufs | pool)`; workers never
//! take the state lock, so the compute path cannot deadlock against the
//! scheduler. Shutdown is graceful: dispatching stops, in-flight tiles
//! drain, every unfinished job completes with [`EngineError::Shutdown`],
//! and all threads are joined.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::blocking::geometry::{Block, BlockGeometry};
use crate::coordinator::{ExecReport, Plan, StageTimes};
use crate::runtime::{extract_tile, writeback_tile, Executor, TileSpec};
use crate::stencil::Grid;

use super::chaos::{ChaosCtx, FaultKind};
use super::scheduler::DeficitRoundRobin;
use super::{Backend, EngineError};

/// Default bound on each client's submission queue; `submit` blocks
/// (backpressure) once this many jobs are waiting.
pub const DEFAULT_QUEUE_DEPTH: usize = 4;

/// A checkpoint observer: called from the scheduler thread at chunk
/// barriers with `(iterations_done, read-buffer grid)` — the exact state
/// an uninterrupted run would have after that many iterations. The sink
/// must be self-contained (no engine or frontend locks): it runs while
/// the scheduler holds the client's state. The wire layer's sink writes a
/// checksummed sidecar file next to the journal (see
/// `engine::wire::checkpoint`).
pub type CheckpointSink = Arc<dyn Fn(usize, &Grid) + Send + Sync>;

/// One unit of work for a session or server client: a grid, its optional
/// power input, and per-job options — iteration-count override, deadline,
/// checkpoint sink, chaos context. `Grid` converts into a `Workload`
/// directly, so `client.submit(grid)` works for the common case.
pub struct Workload {
    grid: Grid,
    power: Option<Grid>,
    iterations: Option<usize>,
    deadline: Option<Duration>,
    checkpoint_every: usize,
    checkpoint: Option<CheckpointSink>,
    chaos: Option<ChaosCtx>,
}

impl std::fmt::Debug for Workload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Workload")
            .field("grid_dims", &self.grid.dims())
            .field("power", &self.power.is_some())
            .field("iterations", &self.iterations)
            .field("deadline", &self.deadline)
            .field("checkpoint_every", &self.checkpoint_every)
            .field("chaos", &self.chaos.is_some())
            .finish()
    }
}

impl Workload {
    pub fn new(grid: Grid) -> Workload {
        Workload {
            grid,
            power: None,
            iterations: None,
            deadline: None,
            checkpoint_every: 0,
            checkpoint: None,
            chaos: None,
        }
    }

    /// Attach a power grid (required for hotspot stencils).
    pub fn power(mut self, power: Grid) -> Workload {
        self.power = Some(power);
        self
    }

    /// Override the plan's iteration count for this job only. The server
    /// reschedules chunks with the plan's step-size set and reuses cached
    /// tile geometry per distinct chunk depth.
    pub fn iterations(mut self, iterations: usize) -> Workload {
        self.iterations = Some(iterations);
        self
    }

    /// Fail the job with [`EngineError::DeadlineExceeded`] if it has not
    /// completed within `deadline` of submission: expired queued jobs
    /// fail fast at the next scheduler pass, an expired active job stops
    /// dispatching and drains its in-flight tiles first.
    pub fn deadline(mut self, deadline: Duration) -> Workload {
        self.deadline = Some(deadline);
        self
    }

    /// Snapshot progress every `every` completed iterations: at each
    /// chunk barrier where at least `every` iterations have accumulated
    /// since the last snapshot, `sink` is called with the iteration count
    /// and the current read buffer. `every == 0` disables snapshots (the
    /// sink is kept but never called — the disabled path the
    /// `resume_vs_restart` ablation measures).
    pub fn checkpoint(mut self, every: usize, sink: CheckpointSink) -> Workload {
        self.checkpoint_every = every;
        self.checkpoint = Some(sink);
        self
    }

    /// Attach a deterministic fault-injection context (see
    /// [`super::chaos::ChaosPlan`]); workers consult it per dispatched
    /// tile.
    pub fn chaos(mut self, ctx: ChaosCtx) -> Workload {
        self.chaos = Some(ctx);
        self
    }
}

impl From<Grid> for Workload {
    fn from(grid: Grid) -> Workload {
        Workload::new(grid)
    }
}

/// A completed job: the updated grid and its execution report.
#[derive(Debug)]
pub struct JobOutput {
    pub grid: Grid,
    pub report: ExecReport,
}

/// Buckets in [`ClientStats::queue_wait_hist`]: bucket `i` counts jobs
/// whose submit→first-dispatch wait fell in `[2^i, 2^(i+1))` µs; the last
/// bucket absorbs everything from ~33 s up.
pub const QUEUE_WAIT_BUCKETS: usize = 16;

/// Per-client service counters, snapshotted by [`ClientSession::stats`].
#[derive(Debug, Clone, Copy, Default)]
pub struct ClientStats {
    pub jobs_submitted: u64,
    pub jobs_completed: u64,
    pub jobs_cancelled: u64,
    pub jobs_failed: u64,
    /// Tiles computed and written back for this client.
    pub tiles_executed: u64,
    /// Useful cell updates completed for this client.
    pub cell_updates: u64,
    /// Longest submit→first-tile-dispatch wait any of this client's jobs
    /// experienced — the fairness observable the stress tests bound.
    pub max_queue_wait: Duration,
    /// Power-of-two histogram of submit→first-dispatch waits in µs (the
    /// wire front door surfaces this per tenant).
    pub queue_wait_hist: [u64; QUEUE_WAIT_BUCKETS],
    /// Cell-update cost the scheduler charged this client (DRR account).
    pub sched_served: u64,
    /// DRR credit-replenishment rounds this client waited through.
    pub sched_rounds: u64,
    /// Times the numeric circuit breaker (`Plan::guard_nonfinite`)
    /// tripped on a NaN/Inf tile result for this client.
    pub nonfinite_trips: u64,
    /// Cell-update cost ledgered against this client for work that
    /// bypassed the DRR ring (cluster-routed jobs at the wire front
    /// door). Kept separate from `sched_served` so the fairness
    /// observable stays an honest account of pool dispatch.
    pub sched_bypassed: u64,
    /// Jobs routed through the cluster layer instead of the pool.
    /// Maintained by the wire front door; always 0 for in-process use.
    pub cluster_jobs: u64,
    /// Shard-loss retry attempts charged to this client's cluster jobs.
    /// Maintained by the wire front door; always 0 for in-process use.
    pub cluster_shard_retries: u64,
}

// ------------------------------------------------------------------ job

/// Result slot + bookkeeping for one submitted job. Shared between the
/// handle, the scheduler and the workers.
struct JobInner {
    id: u64,
    client: usize,
    iterations: usize,
    /// Spec-cache index per chunk (chunk `ci` reads `bufs[ci % 2]`).
    schedule: Vec<usize>,
    /// Fused time-steps per chunk (parallel to `schedule`) — the
    /// scheduler's iteration odometer for checkpoints and reports.
    chunk_steps: Vec<usize>,
    submitted_at: Instant,
    /// Absolute wall-clock deadline, if the workload set one.
    deadline: Option<Instant>,
    checkpoint_every: usize,
    checkpoint: Option<CheckpointSink>,
    chaos: Option<ChaosCtx>,
    cancelled: AtomicBool,
    /// Input grid; becomes the output container at completion.
    grid: Mutex<Option<Grid>>,
    /// Power grid staged into the client slot at activation.
    power: Mutex<Option<Grid>>,
    done: Mutex<Option<Result<JobOutput, EngineError>>>,
    done_cv: Condvar,
    extract_ns: AtomicU64,
    compute_ns: AtomicU64,
}

impl JobInner {
    fn complete(&self, result: Result<JobOutput, EngineError>) {
        let mut done = self.done.lock().expect("job slot poisoned");
        if done.is_none() {
            *done = Some(result);
        }
        self.done_cv.notify_all();
    }
}

/// Handle to a job submitted to an [`EngineServer`] (or, via the
/// [`super::Session`] facade, to a warm session). `wait` blocks until the
/// scheduler completes the job; `cancel` asks the server to abandon it —
/// already-dispatched tiles drain, everything else is skipped, and `wait`
/// returns [`EngineError::Cancelled`].
pub struct JobHandle {
    job: Arc<JobInner>,
    events: Option<Sender<Event>>,
}

impl std::fmt::Debug for JobHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("JobHandle")
            .field("id", &self.job.id)
            .field("done", &self.is_done())
            .finish()
    }
}

impl JobHandle {
    /// Server-wide monotonically increasing job id.
    pub fn id(&self) -> u64 {
        self.job.id
    }

    /// Ask the server to abandon this job. Idempotent; completion races
    /// are benign (a job that finishes first simply stays finished).
    pub fn cancel(&self) {
        self.job.cancelled.store(true, Ordering::SeqCst);
        if let Some(tx) = &self.events {
            let _ = tx.send(Event::Cancel { client: self.job.client, job_id: self.job.id });
        }
    }

    /// Whether the job has completed (successfully or not).
    pub fn is_done(&self) -> bool {
        self.job.done.lock().expect("job slot poisoned").is_some()
    }

    /// Whether the job has completed successfully. Non-blocking: an
    /// in-flight job reports `false`. (Through the [`super::Session`]
    /// facade submissions complete before the handle is returned, so this
    /// is decisive there.)
    pub fn is_ok(&self) -> bool {
        matches!(&*self.job.done.lock().expect("job slot poisoned"), Some(Ok(_)))
    }

    /// The completed job's report, if it has finished successfully.
    pub fn report(&self) -> Option<ExecReport> {
        match &*self.job.done.lock().expect("job slot poisoned") {
            Some(Ok(out)) => Some(out.report.clone()),
            _ => None,
        }
    }

    /// Block until the job completes; `true` when it did within `timeout`.
    /// The bounded-wait primitive the stress tests use to turn a deadlock
    /// into a failure instead of a hang.
    pub fn wait_timeout(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut done = self.job.done.lock().expect("job slot poisoned");
        while done.is_none() {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return false;
            }
            let (guard, _) = self
                .job
                .done_cv
                .wait_timeout(done, left)
                .expect("job slot poisoned");
            done = guard;
        }
        true
    }

    /// Block until the job completes without consuming the handle.
    pub(crate) fn wait_done(&self) {
        let mut done = self.job.done.lock().expect("job slot poisoned");
        while done.is_none() {
            done = self.job.done_cv.wait(done).expect("job slot poisoned");
        }
    }

    /// Consume the handle, yielding the output grid and report (blocks
    /// until the job completes).
    pub fn wait(self) -> Result<JobOutput, EngineError> {
        self.wait_done();
        self.job
            .done
            .lock()
            .expect("job slot poisoned")
            .take()
            .expect("wait_done guarantees completion")
    }

    /// A handle that was born failed (validation error at submit time) —
    /// used by the [`super::Session`] facade, which never returns errors
    /// from `submit` itself.
    pub(crate) fn failed(err: EngineError) -> JobHandle {
        let job = Arc::new(JobInner {
            id: u64::MAX,
            client: usize::MAX,
            iterations: 0,
            schedule: Vec::new(),
            chunk_steps: Vec::new(),
            submitted_at: Instant::now(),
            deadline: None,
            checkpoint_every: 0,
            checkpoint: None,
            chaos: None,
            cancelled: AtomicBool::new(false),
            grid: Mutex::new(None),
            power: Mutex::new(None),
            done: Mutex::new(Some(Err(err))),
            done_cv: Condvar::new(),
            extract_ns: AtomicU64::new(0),
            compute_ns: AtomicU64::new(0),
        });
        JobHandle { job, events: None }
    }
}

// ------------------------------------------------------------ client state

/// Warm per-client execution state, shared with the workers: the plan,
/// its executor, the geometry cache and the persistent grid double
/// buffer. This is exactly the state a single-tenant `Session` used to
/// own — the server holds one per client.
struct ClientShared {
    plan: Plan,
    exec: Box<dyn Executor + Send + Sync>,
    /// One `(spec, blocks)` per distinct chunk depth seen so far; grows
    /// when a submission's iteration override needs a new depth.
    specs: RwLock<Vec<(TileSpec, Vec<Block>)>>,
    /// The role-alternating grid pair: chunk `ci` reads `bufs[ci % 2]`
    /// and writes `bufs[(ci + 1) % 2]`. Allocated once per client.
    bufs: [RwLock<Grid>; 2],
    /// Power grid staged per active job (moved in, not copied).
    power: RwLock<Option<Grid>>,
    /// Whether the plan's (program, coefficients) pair is provably
    /// non-divergent ([`crate::analysis::Stability::guard_skippable`]),
    /// computed once at open. Only meaningful when `plan.guard_nonfinite`
    /// is set.
    guard_skippable: bool,
    /// Set per job at staging time when `guard_skippable` holds and the
    /// staged input is all-finite with magnitude headroom: the per-tile
    /// circuit-breaker scan is then provably redundant and skipped.
    /// One job is active per client at a time, so a plain flag suffices.
    guard_skip: AtomicBool,
}

impl ClientShared {
    /// Index of the cached `(spec, blocks)` entry for a chunk of `steps`,
    /// building (and support-checking) it on first use.
    fn ensure_spec(&self, steps: usize) -> Result<usize, EngineError> {
        if let Some(i) = self
            .specs
            .read()
            .expect("spec cache poisoned")
            .iter()
            .position(|(sp, _)| sp.steps == steps)
        {
            return Ok(i);
        }
        let spec = self.plan.tile_spec(steps);
        if !self.exec.supports(&spec) {
            return Err(EngineError::InvalidPlan(format!(
                "executor {} lacks tile program {}",
                self.exec.backend_name(),
                spec.artifact_name()
            )));
        }
        let def = self.plan.stencil.def();
        let geom =
            BlockGeometry::tiled(&self.plan.grid_dims, &self.plan.tile, def.radius * steps);
        let mut specs = self.specs.write().expect("spec cache poisoned");
        // re-check under the write lock (another submitter may have won)
        if let Some(i) = specs.iter().position(|(sp, _)| sp.steps == steps) {
            return Ok(i);
        }
        specs.push((spec, geom.blocks().collect()));
        Ok(specs.len() - 1)
    }
}

/// The job the scheduler is currently running for one client.
struct ActiveJob {
    job: Arc<JobInner>,
    chunk: usize,
    /// Next block index to dispatch within the current chunk.
    next_block: usize,
    chunk_done: usize,
    /// Block count and per-tile cell-update cost of the current chunk.
    chunk_blocks: usize,
    tile_cost: u64,
    /// This job's dispatched-but-not-written tiles.
    inflight: usize,
    started: Option<Instant>,
    activated: Instant,
    tiles_executed: u64,
    redundant: u64,
    write_ns: u64,
    failed: Option<EngineError>,
    /// Iterations completed at the last chunk barrier (the checkpoint
    /// odometer).
    iters_done: usize,
    /// Iteration count of the last snapshot taken.
    last_ckpt: usize,
}

#[derive(Debug, Clone, Copy, Default)]
struct ClientCounters {
    jobs_submitted: u64,
    jobs_completed: u64,
    jobs_cancelled: u64,
    jobs_failed: u64,
    tiles_executed: u64,
    cell_updates: u64,
    max_queue_wait: Duration,
    queue_wait_hist: [u64; QUEUE_WAIT_BUCKETS],
    nonfinite_trips: u64,
}

struct ClientState {
    shared: Arc<ClientShared>,
    queue: VecDeque<Arc<JobInner>>,
    active: Option<ActiveJob>,
    queue_cap: usize,
    closed: bool,
    stats: ClientCounters,
}

// ------------------------------------------------------------- server core

/// What a compute worker reports back for one tile.
enum TileFailure {
    /// The job was cancelled before this tile computed (nothing ran).
    Cancelled,
    /// The executor failed on this tile.
    Exec(String),
    /// The numeric circuit breaker found NaN/Inf in the tile result.
    NonFinite { tile: usize, iter: usize },
}

/// Scheduler event-loop messages. Everything that mutates cross-client
/// state flows through this one channel, so the scheduler never races.
enum Event {
    /// Something changed (submission, client close) — re-run the pump.
    Wake,
    /// A worker finished (or skipped) one tile.
    TileDone {
        client: usize,
        job_id: u64,
        block_i: usize,
        out: Result<Vec<f32>, TileFailure>,
        extract_ns: u64,
        compute_ns: u64,
    },
    /// Abandon one job.
    Cancel { client: usize, job_id: u64 },
    /// Graceful shutdown: drain in-flight tiles, fail the rest.
    Shutdown,
}

/// One dispatched tile: everything a worker needs, with no access to the
/// scheduler's state.
struct TileTask {
    shared: Arc<ClientShared>,
    job: Arc<JobInner>,
    client: usize,
    spec_i: usize,
    /// Read-buffer role for this chunk.
    src: usize,
    block_i: usize,
    /// Iterations complete before this tile's chunk (for `NonFinite`
    /// reporting).
    base_iter: usize,
    /// Stable `(chunk, block)` key for chaos decisions.
    tile_key: u64,
}

struct TaskQueue {
    queue: VecDeque<TileTask>,
    closed: bool,
}

struct SchedState {
    clients: Vec<Option<ClientState>>,
    drr: DeficitRoundRobin,
    /// Dispatched-but-not-written tiles across all clients — the window
    /// that bounds both memory and scheduling latency.
    inflight: usize,
    shutting_down: bool,
}

struct ServerInner {
    state: Mutex<SchedState>,
    /// Signalled when queue space frees up or shutdown begins; submitters
    /// block here for backpressure.
    space_cv: Condvar,
    tasks: Mutex<TaskQueue>,
    task_cv: Condvar,
    /// Recirculating tile-buffer pool shared by all clients.
    pool: Mutex<Vec<Vec<f32>>>,
    pool_misses: AtomicU64,
    workers: usize,
    inflight_cap: usize,
    next_job_id: AtomicU64,
}

impl ServerInner {
    fn take_buf(&self) -> Vec<f32> {
        match self.pool.lock().expect("tile pool poisoned").pop() {
            Some(buf) => buf,
            None => {
                self.pool_misses.fetch_add(1, Ordering::Relaxed);
                Vec::new()
            }
        }
    }

    fn release_buf(&self, buf: Vec<f32>) {
        // Always recirculate: at most `inflight_cap` buffers exist, so the
        // pool is naturally bounded and `fresh_tile_allocs` can never
        // exceed `tile_pool_capacity`.
        self.pool.lock().expect("tile pool poisoned").push(buf);
    }
}

/// A process-wide server multiplexing many concurrent clients over ONE
/// shared worker pool. Open tenants with [`EngineServer::open`]; stop with
/// [`EngineServer::shutdown`] (also runs on drop).
pub struct EngineServer {
    inner: Arc<ServerInner>,
    events: Sender<Event>,
    scheduler: Option<JoinHandle<()>>,
    worker_handles: Vec<JoinHandle<()>>,
}

impl EngineServer {
    /// Start a server with `workers` compute threads (clamped to ≥ 1)
    /// plus one scheduler thread. The pool is spawned once, here — every
    /// client and every job reuses it.
    pub fn start(workers: usize) -> EngineServer {
        let workers = workers.max(1);
        let inner = Arc::new(ServerInner {
            state: Mutex::new(SchedState {
                clients: Vec::new(),
                drr: DeficitRoundRobin::new(1),
                inflight: 0,
                shutting_down: false,
            }),
            space_cv: Condvar::new(),
            tasks: Mutex::new(TaskQueue { queue: VecDeque::new(), closed: false }),
            task_cv: Condvar::new(),
            pool: Mutex::new(Vec::new()),
            pool_misses: AtomicU64::new(0),
            workers,
            // Dispatch window: enough tiles in flight to keep every worker
            // busy plus a small margin, small enough that DRR preemption
            // is prompt and buffer memory stays bounded.
            inflight_cap: 2 * workers + 2,
            next_job_id: AtomicU64::new(0),
        });
        let (tx, rx) = channel::<Event>();
        let sched_inner = Arc::clone(&inner);
        let scheduler = std::thread::spawn(move || scheduler_loop(&sched_inner, rx));
        let worker_handles = (0..workers)
            .map(|_| {
                let inner = Arc::clone(&inner);
                let tx = tx.clone();
                std::thread::spawn(move || worker_loop(&inner, &tx))
            })
            .collect();
        EngineServer { inner, events: tx, scheduler: Some(scheduler), worker_handles }
    }

    /// [`EngineServer::start`] with one worker per available core.
    pub fn start_default() -> EngineServer {
        let workers =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
        EngineServer::start(workers)
    }

    /// Size of the shared worker pool (health/ops introspection).
    pub fn workers(&self) -> usize {
        self.inner.workers
    }

    /// Open a client session for `plan` with the default queue depth.
    pub fn open(&self, plan: Plan) -> Result<ClientSession, EngineError> {
        self.open_with_queue(plan, DEFAULT_QUEUE_DEPTH)
    }

    /// Open a client session whose submission queue holds up to
    /// `queue_depth` waiting jobs; `submit` blocks beyond that
    /// (backpressure). Runs the static auditor over the plan first —
    /// `Error`-level diagnostics reject the open with
    /// [`EngineError::Rejected`] carrying the full report — then
    /// validates the backend and pre-builds tile geometry for every
    /// chunk depth the plan schedules.
    pub fn open_with_queue(
        &self,
        plan: Plan,
        queue_depth: usize,
    ) -> Result<ClientSession, EngineError> {
        let report = crate::analysis::audit_plan(&plan);
        if report.has_errors() {
            return Err(EngineError::Rejected(report));
        }
        self.open_unaudited(plan, queue_depth)
    }

    /// [`EngineServer::open`] minus the static audit — for benchmarks
    /// measuring the auditor's overhead and for callers re-opening a
    /// plan that already passed (e.g. a clone of a live session's plan).
    /// The structural backend/geometry validation still runs.
    pub fn open_trusted(&self, plan: Plan) -> Result<ClientSession, EngineError> {
        self.open_unaudited(plan, DEFAULT_QUEUE_DEPTH)
    }

    fn open_unaudited(
        &self,
        plan: Plan,
        queue_depth: usize,
    ) -> Result<ClientSession, EngineError> {
        plan.backend.validate()?;
        let exec = plan.backend.executor();
        let cells: usize = plan.grid_dims.iter().product();
        let zero = Grid::from_vec(&plan.grid_dims, vec![0.0; cells]);
        let guard_skippable = plan.guard_nonfinite
            && crate::analysis::stability(plan.stencil.def(), &plan.coeffs).guard_skippable();
        let shared = Arc::new(ClientShared {
            plan,
            exec,
            specs: RwLock::new(Vec::new()),
            bufs: [RwLock::new(zero.clone()), RwLock::new(zero)],
            power: RwLock::new(None),
            guard_skippable,
            guard_skip: AtomicBool::new(false),
        });
        for &steps in &shared.plan.chunks {
            shared.ensure_spec(steps)?;
        }
        let mut st = self.inner.state.lock().expect("server state poisoned");
        if st.shutting_down {
            return Err(EngineError::Shutdown);
        }
        let id = st.drr.register();
        if id >= st.clients.len() {
            st.clients.resize_with(id + 1, || None);
        }
        debug_assert!(st.clients[id].is_none(), "client slot reuse out of sync");
        st.clients[id] = Some(ClientState {
            shared: Arc::clone(&shared),
            queue: VecDeque::new(),
            active: None,
            queue_cap: queue_depth.max(1),
            closed: false,
            stats: ClientCounters::default(),
        });
        Ok(ClientSession {
            inner: Arc::clone(&self.inner),
            events: self.events.clone(),
            shared,
            id,
        })
    }

    /// Size of the shared compute pool.
    pub fn worker_threads(&self) -> usize {
        self.inner.workers
    }

    /// Compute threads spawned over the server's lifetime — equals
    /// [`EngineServer::worker_threads`] forever: ONE pool at construction,
    /// shared by every client, never re-spawned. (The scheduler thread is
    /// a coordinator, not a compute worker, and is not counted.)
    pub fn threads_spawned(&self) -> u64 {
        self.inner.workers as u64
    }

    /// Fresh tile-buffer allocations (pool misses) so far; plateaus at
    /// [`EngineServer::tile_pool_capacity`] once the pool is warm.
    pub fn fresh_tile_allocs(&self) -> u64 {
        self.inner.pool_misses.load(Ordering::Relaxed)
    }

    /// Upper bound on distinct tile buffers the server can ever create:
    /// the dispatch window. Buffers always recirculate, so
    /// [`EngineServer::fresh_tile_allocs`] can never exceed this.
    pub fn tile_pool_capacity(&self) -> usize {
        self.inner.inflight_cap
    }

    /// Currently registered clients.
    pub fn clients(&self) -> usize {
        let st = self.inner.state.lock().expect("server state poisoned");
        st.clients.iter().filter(|c| c.is_some()).count()
    }

    /// Graceful shutdown: stop dispatching, drain in-flight tiles,
    /// complete every unfinished job with [`EngineError::Shutdown`], join
    /// the scheduler and the worker pool. Idempotent; runs on drop.
    pub fn shutdown(&mut self) {
        {
            let mut st = self.inner.state.lock().expect("server state poisoned");
            st.shutting_down = true;
        }
        // Unblock submitters waiting for queue space.
        self.inner.space_cv.notify_all();
        let _ = self.events.send(Event::Shutdown);
        if let Some(h) = self.scheduler.take() {
            let _ = h.join();
        }
        {
            let mut q = self.inner.tasks.lock().expect("task queue poisoned");
            q.closed = true;
        }
        self.inner.task_cv.notify_all();
        for h in self.worker_handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for EngineServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ------------------------------------------------------------- client API

/// One tenant of an [`EngineServer`]: its own plan, backend, geometry
/// cache and grid double-buffer, multiplexed over the server's shared
/// worker pool. `Send`, so each client thread can own one.
pub struct ClientSession {
    inner: Arc<ServerInner>,
    events: Sender<Event>,
    shared: Arc<ClientShared>,
    id: usize,
}

impl ClientSession {
    pub fn plan(&self) -> &Plan {
        &self.shared.plan
    }

    pub fn backend(&self) -> Backend {
        self.shared.plan.backend
    }

    /// Scheduler id of this client (diagnostic).
    pub fn client_id(&self) -> usize {
        self.id
    }

    /// Snapshot of this client's service counters.
    pub fn stats(&self) -> ClientStats {
        let st = self.inner.state.lock().expect("server state poisoned");
        let c = st.clients[self.id].as_ref().expect("client registered");
        ClientStats {
            jobs_submitted: c.stats.jobs_submitted,
            jobs_completed: c.stats.jobs_completed,
            jobs_cancelled: c.stats.jobs_cancelled,
            jobs_failed: c.stats.jobs_failed,
            tiles_executed: c.stats.tiles_executed,
            cell_updates: c.stats.cell_updates,
            max_queue_wait: c.stats.max_queue_wait,
            queue_wait_hist: c.stats.queue_wait_hist,
            sched_served: st.drr.served(self.id),
            sched_rounds: st.drr.rounds(self.id),
            nonfinite_trips: c.stats.nonfinite_trips,
            sched_bypassed: st.drr.bypassed(self.id),
            cluster_jobs: 0,
            cluster_shard_retries: 0,
        }
    }

    /// Ledger `cost` cell updates against this client's DRR account
    /// without scheduling anything: the work ran outside the pool (the
    /// wire front door's cluster route) but should still show up in the
    /// tenant's service accounting.
    pub fn record_bypass(&self, cost: u64) {
        let mut st = self.inner.state.lock().expect("server state poisoned");
        st.drr.bypass(self.id, cost);
    }

    /// Submit one workload. Validation failures (shape, power, iteration
    /// schedule) surface here as typed errors; accepted jobs return a
    /// [`JobHandle`] and run asynchronously. Blocks while the client's
    /// queue is full (backpressure); fails fast with
    /// [`EngineError::Shutdown`] once the server is stopping.
    pub fn submit<W: Into<Workload>>(&self, workload: W) -> Result<JobHandle, EngineError> {
        let Workload { grid, power, iterations, deadline, checkpoint_every, checkpoint, chaos } =
            workload.into();
        let plan = &self.shared.plan;
        let def = plan.stencil.def();
        if grid.dims() != plan.grid_dims {
            return Err(EngineError::GridShape {
                expected: plan.grid_dims.clone(),
                got: grid.dims(),
            });
        }
        if power.is_some() != def.has_power {
            return Err(EngineError::PowerMismatch {
                expected: def.has_power,
                got: power.is_some(),
            });
        }
        if let Some(p) = &power {
            if p.dims() != plan.grid_dims {
                return Err(EngineError::PowerMismatch { expected: true, got: true });
            }
        }
        let iterations = iterations.unwrap_or(plan.iterations);
        let chunks = if iterations == plan.iterations {
            plan.chunks.clone()
        } else {
            plan.schedule_for(iterations)
                .map_err(|e| EngineError::InvalidPlan(format!("{e:#}")))?
        };
        let schedule = chunks
            .iter()
            .map(|&s| self.shared.ensure_spec(s))
            .collect::<Result<Vec<_>, _>>()?;

        let job = Arc::new(JobInner {
            id: self.inner.next_job_id.fetch_add(1, Ordering::Relaxed),
            client: self.id,
            iterations,
            schedule,
            chunk_steps: chunks,
            submitted_at: Instant::now(),
            deadline: deadline.map(|d| Instant::now() + d),
            checkpoint_every,
            checkpoint,
            chaos,
            cancelled: AtomicBool::new(false),
            grid: Mutex::new(Some(grid)),
            power: Mutex::new(power),
            done: Mutex::new(None),
            done_cv: Condvar::new(),
            extract_ns: AtomicU64::new(0),
            compute_ns: AtomicU64::new(0),
        });
        {
            let mut st = self.inner.state.lock().expect("server state poisoned");
            loop {
                if st.shutting_down {
                    return Err(EngineError::Shutdown);
                }
                let c = st.clients[self.id].as_mut().expect("client registered");
                if c.closed {
                    return Err(EngineError::Shutdown);
                }
                if c.queue.len() < c.queue_cap {
                    break;
                }
                st = self.inner.space_cv.wait(st).expect("server state poisoned");
            }
            let c = st.clients[self.id].as_mut().expect("client registered");
            c.queue.push_back(Arc::clone(&job));
            c.stats.jobs_submitted += 1;
        }
        if self.events.send(Event::Wake).is_err() {
            // Scheduler is gone: nothing will ever run this job. Complete
            // it so no handle can hang, and report the loss.
            job.complete(Err(EngineError::WorkerLost));
            return Err(EngineError::WorkerLost);
        }
        Ok(JobHandle { job, events: Some(self.events.clone()) })
    }

    /// Submit several workloads back-to-back (queueing permitting).
    pub fn submit_batch<I>(&self, workloads: I) -> Vec<Result<JobHandle, EngineError>>
    where
        I: IntoIterator,
        I::Item: Into<Workload>,
    {
        workloads.into_iter().map(|w| self.submit(w)).collect()
    }
}

impl Drop for ClientSession {
    fn drop(&mut self) {
        if let Ok(mut st) = self.inner.state.lock() {
            if let Some(Some(c)) = st.clients.get_mut(self.id) {
                c.closed = true;
            }
        }
        // Queued jobs (their handles are still out there) finish normally;
        // the scheduler reaps the slot once the client drains.
        let _ = self.events.send(Event::Wake);
    }
}

// -------------------------------------------------------------- scheduler

fn scheduler_loop(inner: &Arc<ServerInner>, rx: Receiver<Event>) {
    use std::sync::mpsc::RecvTimeoutError;
    // With no deadlines pending the loop blocks indefinitely on the event
    // channel (the steady state); with one pending it sleeps only until
    // the earliest deadline so expiry is noticed without an event.
    let mut wake_at: Option<Instant> = None;
    loop {
        let ev = match wake_at {
            None => match rx.recv() {
                Ok(ev) => Some(ev),
                Err(_) => break,
            },
            Some(at) => {
                let wait = at
                    .saturating_duration_since(Instant::now())
                    .max(Duration::from_millis(1));
                match rx.recv_timeout(wait) {
                    Ok(ev) => Some(ev),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
        };
        let mut st = inner.state.lock().expect("server state poisoned");
        if let Some(ev) = ev {
            handle_event(&mut st, inner, ev);
        }
        while let Ok(ev) = rx.try_recv() {
            handle_event(&mut st, inner, ev);
        }
        if pump(&mut st, inner) {
            break;
        }
        wake_at = earliest_deadline(&st);
    }
    // Backstop for the senders-dropped exit path: make sure workers die.
    let mut q = inner.tasks.lock().expect("task queue poisoned");
    q.closed = true;
    drop(q);
    inner.task_cv.notify_all();
}

/// Earliest live deadline across all queued and active jobs, so the
/// scheduler can sleep exactly until the next one can expire.
fn earliest_deadline(st: &SchedState) -> Option<Instant> {
    let mut min: Option<Instant> = None;
    for c in st.clients.iter().flatten() {
        let queued = c.queue.iter().filter_map(|j| j.deadline);
        let active = c
            .active
            .as_ref()
            .filter(|a| a.failed.is_none())
            .and_then(|a| a.job.deadline);
        for d in queued.chain(active) {
            min = Some(match min {
                Some(m) => m.min(d),
                None => d,
            });
        }
    }
    min
}

fn handle_event(st: &mut SchedState, inner: &ServerInner, ev: Event) {
    match ev {
        Event::Wake => {}
        Event::Shutdown => st.shutting_down = true,
        Event::Cancel { client, job_id } => {
            let Some(Some(c)) = st.clients.get_mut(client) else { return };
            if let Some(i) = c.queue.iter().position(|j| j.id == job_id) {
                let job = c.queue.remove(i).expect("index in range");
                c.stats.jobs_cancelled += 1;
                job.complete(Err(EngineError::Cancelled));
                inner.space_cv.notify_all();
            }
            // An active job's cancelled flag is already set by the handle;
            // the pump reaps it once its in-flight tiles drain.
        }
        Event::TileDone { client, job_id, block_i, out, extract_ns, compute_ns } => {
            st.inflight -= 1;
            let Some(Some(c)) = st.clients.get_mut(client) else { return };
            let shared = Arc::clone(&c.shared);
            let Some(a) = c.active.as_mut() else { return };
            debug_assert_eq!(a.job.id, job_id, "tile for a non-active job");
            a.inflight -= 1;
            a.chunk_done += 1;
            a.job.extract_ns.fetch_add(extract_ns, Ordering::Relaxed);
            a.job.compute_ns.fetch_add(compute_ns, Ordering::Relaxed);
            match out {
                Ok(buf) => {
                    let specs = shared.specs.read().expect("spec cache poisoned");
                    let (spec, blocks) = &specs[a.job.schedule[a.chunk]];
                    let block = &blocks[block_i];
                    let dst = (a.chunk + 1) % 2;
                    let t0 = Instant::now();
                    writeback_tile(
                        &mut shared.bufs[dst].write().expect("grid pair poisoned"),
                        block,
                        &shared.plan.tile,
                        &buf,
                    );
                    a.write_ns += t0.elapsed().as_nanos() as u64;
                    a.tiles_executed += 1;
                    c.stats.tiles_executed += 1;
                    let useful: usize =
                        block.compute.iter().map(|(lo, hi)| hi - lo).product();
                    a.redundant += (spec.cells() - useful) as u64 * spec.steps as u64;
                    drop(specs);
                    inner.release_buf(buf);
                }
                Err(TileFailure::Cancelled) => {}
                Err(TileFailure::Exec(msg)) => {
                    if a.failed.is_none() {
                        a.failed = Some(EngineError::Execution(msg));
                        // stop dispatching the rest of this chunk
                        a.next_block = a.chunk_blocks;
                    }
                }
                Err(TileFailure::NonFinite { tile, iter }) => {
                    c.stats.nonfinite_trips += 1;
                    if a.failed.is_none() {
                        a.failed = Some(EngineError::NonFinite { tile, iter });
                        a.next_block = a.chunk_blocks;
                    }
                }
            }
            advance_chunk(st, inner, client);
        }
    }
}

/// Chunk barrier + job completion for one client, called after each tile
/// lands. Failed or cancelled jobs complete once their in-flight tiles
/// have drained; healthy jobs advance to the next chunk when every block
/// of the current one is written back.
fn advance_chunk(st: &mut SchedState, inner: &ServerInner, client: usize) {
    let Some(Some(c)) = st.clients.get_mut(client) else { return };
    let shared = Arc::clone(&c.shared);
    let Some(a) = c.active.as_mut() else { return };
    // Deadline check for the active job: stop dispatching, drain what is
    // already in flight, fail with the typed error below.
    if a.failed.is_none()
        && !a.job.cancelled.load(Ordering::SeqCst)
        && a.job.deadline.is_some_and(|d| Instant::now() >= d)
    {
        a.failed = Some(EngineError::DeadlineExceeded);
        a.next_block = a.chunk_blocks;
    }
    if a.failed.is_some() || a.job.cancelled.load(Ordering::SeqCst) {
        if a.inflight == 0 {
            let a = c.active.take().expect("checked above");
            *shared.power.write().expect("power slot poisoned") = None;
            let err = match a.failed {
                Some(e) => {
                    c.stats.jobs_failed += 1;
                    e
                }
                None => {
                    c.stats.jobs_cancelled += 1;
                    EngineError::Cancelled
                }
            };
            a.job.complete(Err(err));
        }
        return;
    }
    if a.chunk_done < a.chunk_blocks {
        return;
    }
    a.chunk += 1;
    a.iters_done += a.job.chunk_steps[a.chunk - 1];
    if a.chunk < a.job.schedule.len() {
        // Chunk barrier: the freshly written buffer (`bufs[a.chunk % 2]`,
        // the next chunk's read role) IS the grid state after
        // `iters_done` iterations — snapshot it if one is due. Final
        // results never checkpoint; completion supersedes.
        let due = a.job.checkpoint_every > 0
            && a.iters_done - a.last_ckpt >= a.job.checkpoint_every;
        if due {
            if let Some(sink) = &a.job.checkpoint {
                let g = shared.bufs[a.chunk % 2].read().expect("grid pair poisoned");
                sink(a.iters_done, &g);
                drop(g);
                a.last_ckpt = a.iters_done;
            }
        }
        // next pass over the grid: roles swap, counters reset
        let specs = shared.specs.read().expect("spec cache poisoned");
        let (spec, blocks) = &specs[a.job.schedule[a.chunk]];
        a.chunk_blocks = blocks.len();
        a.tile_cost = (spec.cells() * spec.steps) as u64;
        drop(specs);
        a.next_block = 0;
        a.chunk_done = 0;
        st.drr.enqueue(client);
        return;
    }
    // job complete: copy the final buffer out, build the report
    let a = c.active.take().expect("checked above");
    let passes = a.job.schedule.len();
    let mut grid = a
        .job
        .grid
        .lock()
        .expect("job grid poisoned")
        .take()
        .expect("grid present until completion");
    grid.data_mut().copy_from_slice(
        shared.bufs[passes % 2]
            .read()
            .expect("grid pair poisoned")
            .data(),
    );
    *shared.power.write().expect("power slot poisoned") = None;
    let cell_updates =
        shared.plan.grid_dims.iter().product::<usize>() as u64 * a.job.iterations as u64;
    c.stats.jobs_completed += 1;
    c.stats.cell_updates += cell_updates;
    let ns = |v: u64| Duration::from_nanos(v);
    let report = ExecReport {
        iterations: a.job.iterations,
        passes,
        tiles_executed: a.tiles_executed,
        cell_updates,
        redundant_updates: a.redundant,
        elapsed: a.activated.elapsed(),
        backend: shared.plan.backend.session_label(),
        stages: Some(StageTimes {
            extract: ns(a.job.extract_ns.load(Ordering::Relaxed)),
            compute: ns(a.job.compute_ns.load(Ordering::Relaxed)),
            write: ns(a.write_ns),
        }),
    };
    a.job.complete(Ok(JobOutput { grid, report }));
}

/// Activation + dispatch. Returns `true` when the scheduler should exit
/// (shutdown finished draining).
fn pump(st: &mut SchedState, inner: &ServerInner) -> bool {
    if st.shutting_down {
        if st.inflight > 0 {
            return false; // keep draining TileDone events
        }
        finish_shutdown(st, inner);
        return true;
    }
    for id in 0..st.clients.len() {
        settle_client(st, inner, id);
    }
    dispatch(st, inner);
    false
}

/// Reap finished/cancelled state and activate the next queued job for one
/// client; mark it runnable in the DRR ring if it has dispatchable tiles.
fn settle_client(st: &mut SchedState, inner: &ServerInner, id: usize) {
    // Cancelled-before-dispatch active jobs have no tiles in flight and
    // never receive a TileDone; reap them here.
    advance_chunk(st, inner, id);
    let Some(Some(c)) = st.clients.get_mut(id) else { return };
    // Expired queued jobs fail fast — no activation, no staging. A job
    // that is both cancelled and expired resolves as Cancelled (the
    // tenant's explicit request wins) via the activation loop below.
    let now = Instant::now();
    let mut qi = 0;
    while qi < c.queue.len() {
        let expired = c.queue[qi].deadline.is_some_and(|d| now >= d)
            && !c.queue[qi].cancelled.load(Ordering::SeqCst);
        if expired {
            let job = c.queue.remove(qi).expect("index in range");
            c.stats.jobs_failed += 1;
            job.complete(Err(EngineError::DeadlineExceeded));
            inner.space_cv.notify_all();
        } else {
            qi += 1;
        }
    }
    while c.active.is_none() {
        let Some(job) = c.queue.pop_front() else { break };
        inner.space_cv.notify_all();
        if job.cancelled.load(Ordering::SeqCst) {
            c.stats.jobs_cancelled += 1;
            job.complete(Err(EngineError::Cancelled));
            continue;
        }
        // Stage the job into the client's warm double buffer: input into
        // the pass-0 read grid, power into the shared slot.
        {
            let g = job.grid.lock().expect("job grid poisoned");
            let g = g.as_ref().expect("grid present until completion");
            c.shared.bufs[0]
                .write()
                .expect("grid pair poisoned")
                .data_mut()
                .copy_from_slice(g.data());
            // For a statically non-divergent plan, one input scan here
            // makes the per-tile circuit-breaker scan provably redundant:
            // finite inputs with headroom stay finite under gain ≤ 1.
            let skip = c.shared.guard_skippable
                && g.data()
                    .iter()
                    .all(|v| v.is_finite() && v.abs() <= crate::analysis::GUARD_HEADROOM);
            c.shared.guard_skip.store(skip, Ordering::Relaxed);
        }
        *c.shared.power.write().expect("power slot poisoned") =
            job.power.lock().expect("job power poisoned").take();
        let specs = c.shared.specs.read().expect("spec cache poisoned");
        let (spec, blocks) = &specs[job.schedule[0]];
        let chunk_blocks = blocks.len();
        let tile_cost = (spec.cells() * spec.steps) as u64;
        drop(specs);
        c.active = Some(ActiveJob {
            job,
            chunk: 0,
            next_block: 0,
            chunk_done: 0,
            chunk_blocks,
            tile_cost,
            inflight: 0,
            started: None,
            activated: Instant::now(),
            tiles_executed: 0,
            redundant: 0,
            write_ns: 0,
            failed: None,
            iters_done: 0,
            last_ckpt: 0,
        });
    }
    let runnable = c.active.as_ref().is_some_and(|a| {
        a.failed.is_none()
            && !a.job.cancelled.load(Ordering::SeqCst)
            && a.next_block < a.chunk_blocks
    });
    if runnable {
        st.drr.enqueue(id);
    } else if c.closed && c.queue.is_empty() && c.active.is_none() {
        st.clients[id] = None;
        st.drr.deregister(id);
    }
}

/// Fill the dispatch window with DRR-picked tiles.
fn dispatch(st: &mut SchedState, inner: &ServerInner) {
    let mut dispatched = 0usize;
    while st.inflight < inner.inflight_cap {
        let picked = {
            let SchedState { clients, drr, .. } = st;
            drr.next(|id| {
                let a = clients.get(id)?.as_ref()?.active.as_ref()?;
                if a.failed.is_some() || a.job.cancelled.load(Ordering::SeqCst) {
                    return None;
                }
                (a.next_block < a.chunk_blocks).then_some(a.tile_cost)
            })
        };
        let Some(id) = picked else { break };
        let c = st.clients[id].as_mut().expect("picked client exists");
        let a = c.active.as_mut().expect("picked client has an active job");
        if a.started.is_none() {
            let now = Instant::now();
            a.started = Some(now);
            let wait = now.duration_since(a.job.submitted_at);
            if wait > c.stats.max_queue_wait {
                c.stats.max_queue_wait = wait;
            }
            // log2 bucket of the wait in µs; waits under 1 µs land in
            // bucket 0, the last bucket catches the unbounded tail.
            let us = (wait.as_micros() as u64).max(1);
            let bucket = (63 - us.leading_zeros() as usize).min(QUEUE_WAIT_BUCKETS - 1);
            c.stats.queue_wait_hist[bucket] += 1;
        }
        let task = TileTask {
            shared: Arc::clone(&c.shared),
            job: Arc::clone(&a.job),
            client: id,
            spec_i: a.job.schedule[a.chunk],
            src: a.chunk % 2,
            block_i: a.next_block,
            base_iter: a.iters_done,
            tile_key: ((a.chunk as u64) << 32) | a.next_block as u64,
        };
        a.next_block += 1;
        a.inflight += 1;
        st.inflight += 1;
        let mut q = inner.tasks.lock().expect("task queue poisoned");
        q.queue.push_back(task);
        drop(q);
        dispatched += 1;
    }
    match dispatched {
        0 => {}
        1 => inner.task_cv.notify_one(),
        _ => inner.task_cv.notify_all(),
    }
}

/// Complete every unfinished job. Runs once all in-flight tiles have
/// drained. A job whose cancel flag is set completes as `Cancelled`, not
/// `Shutdown` — the tenant asked for it to stop before the server did,
/// and that precedence holds even when the Cancel *event* never reached
/// the scheduler (racing cancel/shutdown threads, or events dropped at
/// scheduler exit).
fn finish_shutdown(st: &mut SchedState, inner: &ServerInner) {
    let mut complete = |c: &mut ClientState, job: &JobInner| {
        if job.cancelled.load(Ordering::SeqCst) {
            c.stats.jobs_cancelled += 1;
            job.complete(Err(EngineError::Cancelled));
        } else {
            c.stats.jobs_failed += 1;
            job.complete(Err(EngineError::Shutdown));
        }
    };
    for slot in &mut st.clients {
        let Some(c) = slot else { continue };
        if let Some(a) = c.active.take() {
            debug_assert_eq!(a.inflight, 0, "shutdown before drain completed");
            *c.shared.power.write().expect("power slot poisoned") = None;
            complete(c, &a.job);
        }
        while let Some(job) = c.queue.pop_front() {
            complete(c, &job);
        }
    }
    inner.space_cv.notify_all();
}

// ---------------------------------------------------------------- workers

/// Compute-worker body: pop a tile task, extract the tile from the owning
/// client's read buffer, run the client's executor into a pooled buffer,
/// report the result as an event. Workers never touch the scheduler's
/// state lock, and they drop every grid/spec guard before sending, so the
/// scheduler can safely take write locks when the event arrives.
fn worker_loop(inner: &Arc<ServerInner>, events: &Sender<Event>) {
    let mut tile = Vec::new();
    let mut ptile = Vec::new();
    loop {
        let task = {
            let mut q = inner.tasks.lock().expect("task queue poisoned");
            loop {
                if let Some(t) = q.queue.pop_front() {
                    break t;
                }
                if q.closed {
                    return;
                }
                q = inner.task_cv.wait(q).expect("task queue poisoned");
            }
        };
        // A panicking tile (a pathological runtime-defined program, a
        // poisoned lock) must not leak its inflight slot — that would
        // hang the job's wait() and deadlock shutdown's drain. Contain
        // the panic and report the tile as a typed execution failure; the
        // worker itself stays alive. (A buffer popped before the panic
        // may be lost, so the fresh-allocs <= pool-capacity invariant is
        // guaranteed only for panic-free executors.)
        let ev = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_task(inner, &task, &mut tile, &mut ptile)
        }))
        .unwrap_or_else(|_| Event::TileDone {
            client: task.client,
            job_id: task.job.id,
            block_i: task.block_i,
            out: Err(TileFailure::Exec("worker panicked while executing the tile".into())),
            extract_ns: 0,
            compute_ns: 0,
        });
        if events.send(ev).is_err() {
            return; // scheduler is gone; server is tearing down
        }
    }
}

fn run_task(
    inner: &ServerInner,
    task: &TileTask,
    tile: &mut Vec<f32>,
    ptile: &mut Vec<f32>,
) -> Event {
    let (client, job_id, block_i) = (task.client, task.job.id, task.block_i);
    if task.job.cancelled.load(Ordering::SeqCst) {
        // Fast cancel: skip the compute, but still report the tile so the
        // scheduler's drain accounting stays exact.
        return Event::TileDone {
            client,
            job_id,
            block_i,
            out: Err(TileFailure::Cancelled),
            extract_ns: 0,
            compute_ns: 0,
        };
    }
    // Deterministic chaos: the same (job, attempt, tile) key always draws
    // the same fault, so injected failures replay bit-identically.
    if let Some(ch) = &task.job.chaos {
        if ch.plan.should(FaultKind::SlowTile, ch.job, ch.attempt, task.tile_key) {
            std::thread::sleep(Duration::from_millis(2));
        }
        if ch.plan.should(FaultKind::ExecFail, ch.job, ch.attempt, task.tile_key) {
            return Event::TileDone {
                client,
                job_id,
                block_i,
                out: Err(TileFailure::Exec(
                    "chaos: injected tile execution failure".into(),
                )),
                extract_ns: 0,
                compute_ns: 0,
            };
        }
    }
    let shared = &task.shared;
    let specs = shared.specs.read().expect("spec cache poisoned");
    let (spec, blocks) = &specs[task.spec_i];
    let block = &blocks[block_i];
    let cur = shared.bufs[task.src].read().expect("grid pair poisoned");
    let power = shared.power.read().expect("power slot poisoned");
    let t0 = Instant::now();
    extract_tile(&cur, block, &shared.plan.tile, tile);
    let pw = power.as_ref().map(|pg| {
        extract_tile(pg, block, &shared.plan.tile, ptile);
        ptile.as_slice()
    });
    let t1 = Instant::now();
    let mut out = inner.take_buf();
    let res = shared.exec.run_tile_into(spec, tile, pw, &shared.plan.coeffs, &mut out);
    let compute_ns = t1.elapsed().as_nanos() as u64;
    let extract_ns = (t1 - t0).as_nanos() as u64;
    let out = match res {
        // The numeric circuit breaker: an opt-in scan over the tile
        // result, so silent NaN/Inf poison becomes a typed, retryable
        // failure at the tile where it first appeared.
        Ok(())
            if shared.plan.guard_nonfinite
                && !shared.guard_skip.load(Ordering::Relaxed)
                && out.iter().any(|v| !v.is_finite()) =>
        {
            inner.release_buf(out);
            Err(TileFailure::NonFinite {
                tile: block_i,
                iter: task.base_iter + spec.steps,
            })
        }
        Ok(()) => Ok(out),
        Err(e) => {
            // Recirculate the buffer of a failed tile so errors never
            // shrink the pool.
            inner.release_buf(out);
            Err(TileFailure::Exec(format!("{e:#}")))
        }
    };
    Event::TileDone { client, job_id, block_i, out, extract_ns, compute_ns }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::PlanBuilder;
    use crate::stencil::{reference, StencilKind};

    fn plan(dims: &[usize], iters: usize) -> Plan {
        PlanBuilder::new(StencilKind::Diffusion2D)
            .grid_dims(dims.to_vec())
            .iterations(iters)
            .tile(vec![32, 32])
            .build()
            .unwrap()
    }

    #[test]
    fn one_client_matches_reference() {
        let mut server = EngineServer::start(2);
        let client = server.open(plan(&[64, 64], 5)).unwrap();
        let mut grid = Grid::new2d(64, 64);
        grid.fill_random(3, 0.0, 1.0);
        let want = reference::run(
            StencilKind::Diffusion2D,
            &grid,
            None,
            StencilKind::Diffusion2D.def().default_coeffs,
            5,
        );
        let out = client.submit(grid).unwrap().wait().unwrap();
        assert!(out.grid.max_abs_diff(&want) < 1e-3);
        assert_eq!(out.report.iterations, 5);
        assert!(out.report.tiles_executed > 0);
        let stats = client.stats();
        assert_eq!(stats.jobs_completed, 1);
        assert!(stats.sched_served > 0);
        server.shutdown();
    }

    #[test]
    fn two_clients_share_one_pool() {
        let server = EngineServer::start(2);
        let c1 = server.open(plan(&[64, 64], 4)).unwrap();
        let c2 = server
            .open(
                PlanBuilder::new(StencilKind::Diffusion3D)
                    .grid_dims(vec![16, 16, 16])
                    .iterations(3)
                    .tile(vec![8, 8, 8])
                    .build()
                    .unwrap(),
            )
            .unwrap();
        assert_eq!(server.clients(), 2);
        assert_eq!(server.threads_spawned(), 2);
        let mut g1 = Grid::new2d(64, 64);
        g1.fill_random(7, 0.0, 1.0);
        let mut g2 = Grid::new3d(16, 16, 16);
        g2.fill_random(9, 0.0, 1.0);
        let h1 = c1.submit(g1.clone()).unwrap();
        let h2 = c2.submit(g2.clone()).unwrap();
        let o1 = h1.wait().unwrap();
        let o2 = h2.wait().unwrap();
        let w1 = reference::run(
            StencilKind::Diffusion2D,
            &g1,
            None,
            StencilKind::Diffusion2D.def().default_coeffs,
            4,
        );
        let w2 = reference::run(
            StencilKind::Diffusion3D,
            &g2,
            None,
            StencilKind::Diffusion3D.def().default_coeffs,
            3,
        );
        assert!(o1.grid.max_abs_diff(&w1) < 1e-3);
        assert!(o2.grid.max_abs_diff(&w2) < 1e-3);
        // one pool, bounded buffer churn
        assert_eq!(server.threads_spawned(), 2);
        assert!(server.fresh_tile_allocs() <= server.tile_pool_capacity() as u64);
    }

    #[test]
    fn submit_after_shutdown_is_a_typed_error() {
        let mut server = EngineServer::start(1);
        let client = server.open(plan(&[64, 64], 2)).unwrap();
        server.shutdown();
        let err = client.submit(Grid::new2d(64, 64)).unwrap_err();
        assert_eq!(err, EngineError::Shutdown);
    }

    #[test]
    fn cancel_queued_job_reports_cancelled() {
        let mut server = EngineServer::start(1);
        let client = server.open_with_queue(plan(&[96, 96], 12), 8).unwrap();
        // Pile up jobs so later ones are definitely queued, then cancel
        // the tail one.
        let mut handles = Vec::new();
        for s in 0..4u64 {
            let mut g = Grid::new2d(96, 96);
            g.fill_random(s, 0.0, 1.0);
            handles.push(client.submit(g).unwrap());
        }
        let last = handles.pop().unwrap();
        last.cancel();
        let err = last.wait().unwrap_err();
        assert_eq!(err, EngineError::Cancelled);
        for h in handles {
            assert!(h.wait().is_ok());
        }
        let stats = client.stats();
        assert_eq!(stats.jobs_cancelled, 1);
        assert_eq!(stats.jobs_completed, 3);
        server.shutdown();
    }

    #[test]
    fn shutdown_fails_unfinished_jobs_without_hanging() {
        let mut server = EngineServer::start(1);
        let client = server.open_with_queue(plan(&[128, 128], 16), 16).unwrap();
        let handles: Vec<JobHandle> = (0..6u64)
            .map(|s| {
                let mut g = Grid::new2d(128, 128);
                g.fill_random(s, 0.0, 1.0);
                client.submit(g).unwrap()
            })
            .collect();
        server.shutdown();
        let mut finished = 0;
        for h in handles {
            assert!(h.wait_timeout(Duration::from_secs(30)), "job hung after shutdown");
            match h.wait() {
                Ok(_) => finished += 1,
                Err(e) => assert_eq!(e, EngineError::Shutdown),
            }
        }
        // some prefix may have completed before shutdown; the rest must
        // have failed with the typed error, and nothing may hang
        assert!(finished <= 6);
    }

    #[test]
    fn shutdown_reports_cancelled_jobs_as_cancelled() {
        // The lost-event race: a job's cancel *flag* is set but the
        // Cancel *event* never reaches the scheduler before shutdown
        // (concurrent cancel/shutdown threads). finish_shutdown must
        // still honor the flag — Cancelled, not Shutdown.
        let mut server = EngineServer::start(1);
        let client = server.open_with_queue(plan(&[128, 128], 16), 8).unwrap();
        let mut heavy = Grid::new2d(128, 128);
        heavy.fill_random(1, 0.0, 1.0);
        let _a = client.submit(heavy).unwrap();
        let mut g = Grid::new2d(128, 128);
        g.fill_random(2, 0.0, 1.0);
        let b = client.submit(g).unwrap();
        // Model the race directly: flag set, no Cancel event sent.
        b.job.cancelled.store(true, Ordering::SeqCst);
        server.shutdown();
        assert!(b.wait_timeout(Duration::from_secs(30)), "job hung after shutdown");
        match b.wait() {
            // b may have finished before shutdown noticed the flag (the
            // scheduler also resolves flagged jobs to Cancelled).
            Err(EngineError::Cancelled) => {}
            other => panic!("cancelled-then-shutdown job resolved to {other:?}"),
        }
    }

    #[test]
    fn expired_queued_job_fails_fast_with_typed_error() {
        let mut server = EngineServer::start(1);
        let client = server.open_with_queue(plan(&[128, 128], 16), 8).unwrap();
        let mut heavy = Grid::new2d(128, 128);
        heavy.fill_random(1, 0.0, 1.0);
        let shield = client.submit(heavy).unwrap();
        let mut g = Grid::new2d(128, 128);
        g.fill_random(2, 0.0, 1.0);
        // Already-expired deadline: the scheduler's queue sweep must fail
        // it before activation, whatever the shield job's timing.
        let victim = client.submit(Workload::new(g).deadline(Duration::ZERO)).unwrap();
        assert!(victim.wait_timeout(Duration::from_secs(30)), "expired job hung");
        assert_eq!(victim.wait().unwrap_err(), EngineError::DeadlineExceeded);
        assert!(shield.wait().is_ok());
        assert_eq!(client.stats().jobs_failed, 1);
        server.shutdown();
    }

    #[test]
    fn expired_active_job_cancel_drains_with_typed_error() {
        use crate::engine::ChaosPlan;
        let mut server = EngineServer::start(1);
        let client = server.open(plan(&[160, 160], 16)).unwrap();
        let mut g = Grid::new2d(160, 160);
        g.fill_random(3, 0.0, 1.0);
        // slow=1 delays every tile ~2ms: 25 tiles/chunk on one worker
        // guarantees the job is still mid-chunk when the deadline hits.
        let chaos = ChaosCtx {
            plan: Arc::new(ChaosPlan::new(1).rule(FaultKind::SlowTile, 1.0, 0)),
            job: 1,
            attempt: 1,
        };
        let h = client
            .submit(
                Workload::new(g)
                    .deadline(Duration::from_millis(40))
                    .chaos(chaos),
            )
            .unwrap();
        assert!(h.wait_timeout(Duration::from_secs(30)), "expired active job hung");
        assert_eq!(h.wait().unwrap_err(), EngineError::DeadlineExceeded);
        server.shutdown();
    }

    #[test]
    fn nonfinite_guard_trips_typed_error_and_counts() {
        let guarded = PlanBuilder::new(StencilKind::Diffusion2D)
            .grid_dims(vec![64, 64])
            .iterations(8)
            .tile(vec![32, 32])
            .guard_nonfinite(true)
            .build()
            .unwrap();
        let mut server = EngineServer::start(2);
        let client = server.open(guarded).unwrap();
        let mut g = Grid::new2d(64, 64);
        g.fill_random(4, 0.0, 1.0);
        g.data_mut()[64 * 32 + 32] = f32::NAN;
        match client.submit(g.clone()).unwrap().wait() {
            // First chunk fuses 4 steps, so the breaker reports iteration 4.
            Err(EngineError::NonFinite { iter, .. }) => assert_eq!(iter, 4),
            other => panic!("guarded NaN run resolved to {other:?}"),
        }
        assert!(client.stats().nonfinite_trips >= 1);
        assert_eq!(client.stats().jobs_failed, 1);
        server.shutdown();

        // Guard off (default): the same poison propagates silently.
        let mut server = EngineServer::start(2);
        let client = server.open(plan(&[64, 64], 8)).unwrap();
        let out = client.submit(g).unwrap().wait().unwrap();
        assert!(out.grid.data().iter().any(|v| v.is_nan()), "poison vanished");
        assert_eq!(client.stats().nonfinite_trips, 0);
        server.shutdown();
    }

    #[test]
    fn open_rejects_error_level_audit_findings() {
        let mut bad = plan(&[64, 64], 4);
        bad.coeffs[0] = f32::NAN;
        let mut server = EngineServer::start(1);
        match server.open(bad) {
            Err(EngineError::Rejected(report)) => {
                assert!(report.has_errors());
                assert!(report.errors().any(|d| d.code == "E005"), "{report}");
            }
            other => panic!("NaN-coefficient open resolved to {other:?}"),
        }
        // The same shape passes through open_trusted (structural checks
        // only) — the bench hook must not re-audit.
        let trusted = server.open_trusted(plan(&[64, 64], 4)).unwrap();
        drop(trusted);
        server.shutdown();
    }

    #[test]
    fn provably_stable_guarded_plan_skips_scan_but_stays_correct() {
        // Diffusion2D's default coefficients sum to 1: the auditor proves
        // the guard can never trip, the staging scan arms the skip, and
        // the result is bit-identical to the unguarded run.
        let guarded = PlanBuilder::new(StencilKind::Diffusion2D)
            .grid_dims(vec![64, 64])
            .iterations(8)
            .guard_nonfinite(true)
            .build()
            .unwrap();
        let mut server = EngineServer::start(2);
        let client = server.open(guarded).unwrap();
        assert!(client.shared.guard_skippable);
        let mut g = Grid::new2d(64, 64);
        g.fill_random(11, 0.0, 1.0);
        let out = client.submit(g.clone()).unwrap().wait().unwrap();
        assert!(client.shared.guard_skip.load(Ordering::Relaxed));
        assert_eq!(client.stats().nonfinite_trips, 0);
        server.shutdown();

        let mut server = EngineServer::start(2);
        let client = server.open(plan(&[64, 64], 8)).unwrap();
        let base = client.submit(g).unwrap().wait().unwrap();
        assert_eq!(out.grid.data(), base.grid.data(), "skip changed numerics");
        server.shutdown();
    }

    #[test]
    fn chaos_exec_faults_fail_jobs_deterministically() {
        use crate::engine::ChaosPlan;
        let cplan = Arc::new(ChaosPlan::new(9).rule(FaultKind::ExecFail, 1.0, 0));
        let mut server = EngineServer::start(2);
        let client = server.open(plan(&[64, 64], 4)).unwrap();
        let mut g = Grid::new2d(64, 64);
        g.fill_random(5, 0.0, 1.0);
        let ctx = ChaosCtx { plan: Arc::clone(&cplan), job: 7, attempt: 1 };
        let err = client.submit(Workload::new(g).chaos(ctx)).unwrap().wait().unwrap_err();
        match err {
            EngineError::Execution(msg) => assert!(msg.contains("chaos")),
            other => panic!("chaos exec fault resolved to {other:?}"),
        }
        assert!(cplan.injected(FaultKind::ExecFail) >= 1);
        server.shutdown();
    }

    #[test]
    fn checkpoints_fire_at_chunk_barriers_and_resume_is_bit_identical() {
        // 12 iterations over step sizes [4,2,1] → chunks [4,4,4]; with
        // checkpoint_every=4 the sink must fire at 4 and 8 (never at 12 —
        // completion supersedes the final barrier).
        let mut server = EngineServer::start(2);
        let client = server.open(plan(&[64, 64], 12)).unwrap();
        let mut g = Grid::new2d(64, 64);
        g.fill_random(6, 0.0, 1.0);
        let snaps: Arc<Mutex<Vec<(usize, Grid)>>> = Arc::new(Mutex::new(Vec::new()));
        let sink: CheckpointSink = {
            let snaps = Arc::clone(&snaps);
            Arc::new(move |iters, grid| {
                snaps.lock().expect("snaps").push((iters, grid.clone()));
            })
        };
        let full = client
            .submit(Workload::new(g.clone()).checkpoint(4, Arc::clone(&sink)))
            .unwrap()
            .wait()
            .unwrap();
        let taken: Vec<usize> =
            snaps.lock().expect("snaps").iter().map(|(i, _)| *i).collect();
        assert_eq!(taken, vec![4, 8]);

        // Resume from the last snapshot: 4 remaining iterations over the
        // snapshot grid must be bit-identical to the uninterrupted run
        // (the greedy schedule's suffix property).
        let (done, snap) = snaps.lock().expect("snaps").last().cloned().unwrap();
        let resumed = client
            .submit(Workload::new(snap).iterations(12 - done))
            .unwrap()
            .wait()
            .unwrap();
        let same = resumed
            .grid
            .data()
            .iter()
            .zip(full.grid.data())
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "resumed result diverged from the uninterrupted run");

        // every == 0 disables snapshots entirely (the ablation's path).
        snaps.lock().expect("snaps").clear();
        let mut g2 = Grid::new2d(64, 64);
        g2.fill_random(7, 0.0, 1.0);
        client.submit(Workload::new(g2).checkpoint(0, sink)).unwrap().wait().unwrap();
        assert!(snaps.lock().expect("snaps").is_empty());
        server.shutdown();
    }
}
