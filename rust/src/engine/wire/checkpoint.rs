//! Crash-safe mid-job checkpoints: one sidecar file per in-flight job,
//! written next to the journal at grid double-buffer barriers.
//!
//! The engine's greedy temporal schedule has a suffix property (DESIGN
//! §3.4): after `done` of `total` iterations, the remaining schedule is
//! exactly `schedule_for(total - done)`. A checkpoint therefore only
//! needs the iteration counter and the grid bytes at a chunk barrier —
//! resubmitting `total - done` iterations from the snapshot replays the
//! identical tile stream, so a resumed job is *bit-identical* to an
//! uninterrupted run.
//!
//! Snapshots are written atomically (tmp + rename) and carry an FNV-1a
//! checksum over the canonical JSON body, so a torn or corrupted sidecar
//! is detected on load and the frontend falls back to the heal path
//! instead of resuming from poison. Grid bytes ride as base64 of the
//! little-endian f32 encoding ([`GridPayload`]) — the same bit-exact
//! representation the wire uses.

use std::fs;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

use super::protocol::{GridPayload, PlanSpec};

/// One job's resumable state at a chunk barrier.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// The ledger job id this snapshot belongs to.
    pub job: u64,
    /// The wire tenant (session) that owns the job.
    pub tenant: u64,
    /// The attempt the snapshot was taken on; a resume submits attempt
    /// `attempt + 1`.
    pub attempt: u32,
    /// Total iterations the job was submitted with.
    pub total: usize,
    /// Iterations completed at snapshot time (`0 < done < total` for a
    /// resumable checkpoint).
    pub done: usize,
    /// The plan the job runs under, so a rebound frontend can rebuild
    /// the tenant session without the original open request.
    pub plan: PlanSpec,
    /// The grid at the barrier (bit-exact LE-f32 base64).
    pub grid: GridPayload,
    /// The power grid, for stencils that take one (constant across
    /// iterations, but kept here so resume needs no other source).
    pub power: Option<GridPayload>,
}

/// FNV-1a 64-bit over `bytes` — the in-tree checksum for sidecar files
/// (no crates; collision resistance is irrelevant, torn-write detection
/// is the job).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn get_u64(v: &Json, key: &str) -> Result<u64, String> {
    v.get(key)
        .and_then(Json::as_f64)
        .filter(|n| *n >= 0.0 && n.fract() == 0.0)
        .map(|n| n as u64)
        .ok_or_else(|| format!("checkpoint missing integer field {key:?}"))
}

fn get_usize(v: &Json, key: &str) -> Result<usize, String> {
    v.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| format!("checkpoint missing integer field {key:?}"))
}

impl Checkpoint {
    /// Sidecar path for `job` next to `journal`:
    /// `<journal>.ckpt.<job>`. One file per job; overwritten in place at
    /// each barrier, deleted when the job goes terminal.
    pub fn path_for(journal: &Path, job: u64) -> PathBuf {
        PathBuf::from(format!("{}.ckpt.{job}", journal.display()))
    }

    /// The canonical body (everything but the checksum). Serialized
    /// deterministically — `Json` objects are ordered maps — so the crc
    /// computed at save time matches the one recomputed at load time.
    fn body_json(&self) -> Json {
        let mut pairs = vec![
            ("job", Json::Num(self.job as f64)),
            ("tenant", Json::Num(self.tenant as f64)),
            ("attempt", Json::from(self.attempt as usize)),
            ("total", Json::from(self.total)),
            ("done", Json::from(self.done)),
            ("plan", self.plan.to_json()),
            ("grid", self.grid.to_json()),
        ];
        if let Some(p) = &self.power {
            pairs.push(("power", p.to_json()));
        }
        Json::obj(pairs)
    }

    pub fn to_json(&self) -> Json {
        let body = self.body_json();
        let crc = fnv1a64(body.to_string().as_bytes());
        let mut pairs = vec![
            ("job", Json::Num(self.job as f64)),
            ("tenant", Json::Num(self.tenant as f64)),
            ("attempt", Json::from(self.attempt as usize)),
            ("total", Json::from(self.total)),
            ("done", Json::from(self.done)),
            ("plan", self.plan.to_json()),
            ("grid", self.grid.to_json()),
        ];
        if let Some(p) = &self.power {
            pairs.push(("power", p.to_json()));
        }
        pairs.push(("crc", Json::from(format!("{crc:016x}"))));
        Json::obj(pairs)
    }

    /// Parse and *verify*: a crc mismatch (tampered or torn body) is an
    /// error, never a silently-wrong resume point.
    pub fn from_json(v: &Json) -> Result<Checkpoint, String> {
        let crc_hex = v
            .get("crc")
            .and_then(Json::as_str)
            .ok_or_else(|| "checkpoint missing crc".to_string())?;
        let recorded = u64::from_str_radix(crc_hex, 16)
            .map_err(|e| format!("checkpoint crc is not hex: {e}"))?;
        let ck = Checkpoint {
            job: get_u64(v, "job")?,
            tenant: get_u64(v, "tenant")?,
            attempt: get_u64(v, "attempt")? as u32,
            total: get_usize(v, "total")?,
            done: get_usize(v, "done")?,
            plan: PlanSpec::from_json(
                v.get("plan").ok_or_else(|| "checkpoint missing plan".to_string())?,
            )
            .map_err(|e| format!("checkpoint plan: {e}"))?,
            grid: GridPayload::from_json(
                v.get("grid").ok_or_else(|| "checkpoint missing grid".to_string())?,
            )
            .map_err(|e| format!("checkpoint grid: {e}"))?,
            power: match v.get("power") {
                None | Some(Json::Null) => None,
                Some(p) => Some(
                    GridPayload::from_json(p).map_err(|e| format!("checkpoint power: {e}"))?,
                ),
            },
        };
        let computed = fnv1a64(ck.body_json().to_string().as_bytes());
        if computed != recorded {
            return Err(format!(
                "checkpoint crc mismatch: recorded {crc_hex}, computed {computed:016x}"
            ));
        }
        Ok(ck)
    }

    /// Write the sidecar atomically: serialize to `<path>.tmp`, then
    /// rename over `path`, so a crash mid-write never leaves a
    /// half-written file at the load path. With `corrupt` (chaos
    /// injection only) the tail of the JSON is truncated before the
    /// rename — the "disk lied" case the loader must reject.
    pub fn save(&self, path: &Path, corrupt: bool) -> std::io::Result<()> {
        let mut line = self.to_json().to_string();
        if corrupt {
            line.truncate(line.len().saturating_sub(20).max(1));
        }
        let tmp = PathBuf::from(format!("{}.tmp", path.display()));
        fs::write(&tmp, line.as_bytes())?;
        fs::rename(&tmp, path)
    }

    /// Read and verify a sidecar. Any failure — missing file, bad JSON,
    /// missing field, crc mismatch — is a typed `Err`, and the caller
    /// falls back to healing the job instead of resuming it.
    pub fn load(path: &Path) -> Result<Checkpoint, String> {
        let text = fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let v = Json::parse(text.trim())
            .map_err(|e| format!("parse {}: {e}", path.display()))?;
        Checkpoint::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::Grid;

    fn tmp_path(tag: &str) -> PathBuf {
        let n = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos();
        std::env::temp_dir().join(format!(
            "fstencil-ckpt-{tag}-{}-{n}",
            std::process::id()
        ))
    }

    fn sample() -> Checkpoint {
        let mut g = Grid::new2d(6, 5);
        g.fill_random(11, -2.0, 2.0);
        g.data_mut()[3] = -0.0; // sign bit must survive the round trip
        Checkpoint {
            job: 42,
            tenant: 7,
            attempt: 2,
            total: 24,
            done: 8,
            plan: PlanSpec {
                stencil: "diffusion2d".into(),
                grid_dims: vec![6, 5],
                iterations: 24,
                backend: "scalar".into(),
                tile: Some(vec![6, 5]),
                coeffs: None,
                step_sizes: None,
                workers: None,
                guard_nonfinite: Some(true),
                shards: None,
            },
            grid: GridPayload::from_grid(&g),
            power: None,
        }
    }

    #[test]
    fn save_load_round_trips_bit_exactly() {
        let path = tmp_path("roundtrip");
        let ck = sample();
        ck.save(&path, false).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        let (a, b) = (back.grid.to_grid().unwrap(), ck.grid.to_grid().unwrap());
        for (x, y) in a.data().iter().zip(b.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn truncated_sidecar_is_rejected() {
        let path = tmp_path("torn");
        sample().save(&path, true).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn tampered_body_fails_the_crc() {
        let path = tmp_path("tamper");
        let ck = sample();
        ck.save(&path, false).unwrap();
        // Flip the iteration counter in place: still valid JSON, but the
        // recorded crc no longer matches the recomputed one.
        let text = fs::read_to_string(&path).unwrap();
        let bent = text.replace("\"done\":8", "\"done\":12");
        assert_ne!(bent, text, "fixture must actually change the body");
        fs::write(&path, bent).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(err.contains("crc"), "unexpected error: {err}");
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn missing_sidecar_is_an_error_not_a_panic() {
        let err = Checkpoint::load(&tmp_path("missing")).unwrap_err();
        assert!(err.contains("read"), "unexpected error: {err}");
    }

    #[test]
    fn sidecar_paths_are_per_job_next_to_the_journal() {
        let j = PathBuf::from("/var/lib/fstencil/jobs.jsonl");
        assert_eq!(
            Checkpoint::path_for(&j, 9),
            PathBuf::from("/var/lib/fstencil/jobs.jsonl.ckpt.9")
        );
        assert_ne!(Checkpoint::path_for(&j, 1), Checkpoint::path_for(&j, 2));
    }
}
