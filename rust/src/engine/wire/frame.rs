//! The shared frame codec: length-prefixed JSON frames, base64, and the
//! bit-exact grid payload encoding.
//!
//! One frame = a 4-byte big-endian length followed by that many bytes of
//! UTF-8 JSON. Extracted out of [`super::protocol`] so the job protocol
//! and the cluster halo/shard-control messages ([`crate::cluster`]) ride
//! one implementation — there is exactly one framing codec and one base64
//! in the tree, and both protocol layers inherit the same hostile-input
//! guarantees (torn, oversized and garbage frames are typed rejections,
//! never panics or hangs).

use std::io::{Read, Write};

use crate::stencil::Grid;
use crate::util::json::Json;

use super::protocol::WireError;

/// Hard cap on one frame's body. Large enough for a 2048³ f32 grid in
/// base64, small enough that a hostile length prefix cannot OOM the
/// server: oversized frames are rejected before any body byte is read.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

// ---------------------------------------------------------------- framing

/// Encode one frame (length prefix + serialized JSON) into a byte vector.
pub fn encode_frame(msg: &Json) -> Vec<u8> {
    let body = msg.to_string().into_bytes();
    let mut out = Vec::with_capacity(4 + body.len());
    out.extend_from_slice(&(body.len() as u32).to_be_bytes());
    out.extend_from_slice(&body);
    out
}

/// Write one frame to `w` (a single `write_all`, so small frames are one
/// syscall; callers wanting Nagle off set `TCP_NODELAY` on the stream).
pub fn write_frame<W: Write>(w: &mut W, msg: &Json) -> Result<(), WireError> {
    w.write_all(&encode_frame(msg))?;
    w.flush()?;
    Ok(())
}

/// Read exactly `buf.len()` bytes, mapping EOF to [`WireError::Torn`].
fn read_body<R: Read>(r: &mut R, buf: &mut [u8], want: usize) -> Result<(), WireError> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => return Err(WireError::Torn { got, want }),
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// Read one frame. A clean EOF before any header byte is
/// [`WireError::Closed`]; EOF inside the header or body is
/// [`WireError::Torn`]; a hostile length prefix is rejected as
/// [`WireError::Oversized`] *before* the body is read.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Json, WireError> {
    let mut header = [0u8; 4];
    // First byte separately: 0 bytes here is a clean close, not a tear.
    let mut first = [0u8; 1];
    loop {
        match r.read(&mut first) {
            Ok(0) => return Err(WireError::Closed),
            Ok(_) => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    header[0] = first[0];
    read_body(r, &mut header[1..], 4)?;
    let len = u32::from_be_bytes(header) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(WireError::Oversized { len, max: MAX_FRAME_BYTES });
    }
    let mut body = vec![0u8; len];
    read_body(r, &mut body, len)?;
    let text = String::from_utf8(body)
        .map_err(|e| WireError::BadJson(format!("invalid utf-8: {e}")))?;
    Json::parse(&text).map_err(|e| WireError::BadJson(e.to_string()))
}

// ----------------------------------------------------------------- base64

const B64_ALPHABET: &[u8; 64] =
    b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Standard base64 with padding (in-tree substrate; no crates offline).
pub fn b64_encode(data: &[u8]) -> String {
    let mut out = String::with_capacity(data.len().div_ceil(3) * 4);
    for chunk in data.chunks(3) {
        let b = [chunk[0], *chunk.get(1).unwrap_or(&0), *chunk.get(2).unwrap_or(&0)];
        let n = (u32::from(b[0]) << 16) | (u32::from(b[1]) << 8) | u32::from(b[2]);
        out.push(B64_ALPHABET[(n >> 18) as usize & 63] as char);
        out.push(B64_ALPHABET[(n >> 12) as usize & 63] as char);
        out.push(if chunk.len() > 1 { B64_ALPHABET[(n >> 6) as usize & 63] as char } else { '=' });
        out.push(if chunk.len() > 2 { B64_ALPHABET[n as usize & 63] as char } else { '=' });
    }
    out
}

fn b64_val(c: u8) -> Option<u32> {
    match c {
        b'A'..=b'Z' => Some(u32::from(c - b'A')),
        b'a'..=b'z' => Some(u32::from(c - b'a') + 26),
        b'0'..=b'9' => Some(u32::from(c - b'0') + 52),
        b'+' => Some(62),
        b'/' => Some(63),
        _ => None,
    }
}

/// Decode standard base64 (padding required). Rejects bad lengths,
/// foreign characters and misplaced padding with a typed error.
pub fn b64_decode(text: &str) -> Result<Vec<u8>, WireError> {
    let bytes = text.as_bytes();
    if bytes.len() % 4 != 0 {
        return Err(WireError::BadMessage(format!(
            "base64 length {} is not a multiple of 4",
            bytes.len()
        )));
    }
    let mut out = Vec::with_capacity(bytes.len() / 4 * 3);
    for (ci, chunk) in bytes.chunks(4).enumerate() {
        let last = ci + 1 == bytes.len() / 4;
        let pads = chunk.iter().rev().take_while(|&&c| c == b'=').count();
        if pads > 2 || (pads > 0 && !last) {
            return Err(WireError::BadMessage("misplaced base64 padding".into()));
        }
        let mut n = 0u32;
        for &c in &chunk[..4 - pads] {
            n = (n << 6)
                | b64_val(c).ok_or_else(|| {
                    WireError::BadMessage(format!("bad base64 character {:?}", c as char))
                })?;
        }
        n <<= 6 * pads as u32;
        out.push((n >> 16) as u8);
        if pads < 2 {
            out.push((n >> 8) as u8);
        }
        if pads < 1 {
            out.push(n as u8);
        }
    }
    Ok(out)
}

// ----------------------------------------------------------- grid payload

/// A grid on the wire: dims plus base64 of the little-endian f32 bytes.
/// Byte-level encoding means results round-trip *bit*-exactly (NaN
/// payloads included) — JSON numbers would be lossy and 3× bigger.
#[derive(Debug, Clone, PartialEq)]
pub struct GridPayload {
    pub dims: Vec<usize>,
    pub data_b64: String,
}

impl GridPayload {
    pub fn from_grid(grid: &Grid) -> GridPayload {
        GridPayload { dims: grid.dims(), data_b64: b64_encode_f32(grid.data()) }
    }

    pub fn to_grid(&self) -> Result<Grid, WireError> {
        let cells: usize = self.dims.iter().product();
        if self.dims.is_empty() || cells == 0 {
            return Err(WireError::BadMessage(format!("bad grid dims {:?}", self.dims)));
        }
        let data = b64_decode_f32(&self.data_b64)?;
        if data.len() != cells {
            return Err(WireError::BadMessage(format!(
                "grid payload holds {} cells but dims {:?} need {}",
                data.len(),
                self.dims,
                cells
            )));
        }
        Ok(Grid::from_vec(&self.dims, data))
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dims", usize_arr(&self.dims)),
            ("data", Json::from(self.data_b64.clone())),
        ])
    }

    pub fn from_json(v: &Json) -> Result<GridPayload, WireError> {
        Ok(GridPayload {
            dims: req_usize_arr(v, "dims")?,
            data_b64: req_str(v, "data")?.to_string(),
        })
    }
}

/// Base64 of a cell slice's little-endian f32 bytes — the bit-exact cell
/// encoding shared by [`GridPayload`] and the cluster halo slabs (which
/// ship raw row runs without a dims header).
pub fn b64_encode_f32(cells: &[f32]) -> String {
    let mut bytes = Vec::with_capacity(cells.len() * 4);
    for v in cells {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    b64_encode(&bytes)
}

/// Inverse of [`b64_encode_f32`]; rejects byte counts that are not a
/// multiple of the 4-byte cell size.
pub fn b64_decode_f32(text: &str) -> Result<Vec<f32>, WireError> {
    let bytes = b64_decode(text)?;
    if bytes.len() % 4 != 0 {
        return Err(WireError::BadMessage(format!(
            "cell payload holds {} bytes, not a multiple of 4",
            bytes.len()
        )));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

// ------------------------------------------------------------ json access

/// u64 ids ride as JSON numbers; f64 is exact for ids below 2^53, far
/// beyond any journal's lifetime.
pub(crate) fn u64_json(n: u64) -> Json {
    Json::Num(n as f64)
}

pub(crate) fn usize_arr(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::from(x)).collect())
}

pub(crate) fn req_str<'a>(v: &'a Json, key: &str) -> Result<&'a str, WireError> {
    v.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| WireError::BadMessage(format!("missing string field {key:?}")))
}

pub(crate) fn req_u64(v: &Json, key: &str) -> Result<u64, WireError> {
    v.get(key)
        .and_then(Json::as_f64)
        .filter(|n| *n >= 0.0 && n.fract() == 0.0)
        .map(|n| n as u64)
        .ok_or_else(|| WireError::BadMessage(format!("missing integer field {key:?}")))
}

pub(crate) fn req_usize(v: &Json, key: &str) -> Result<usize, WireError> {
    v.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| WireError::BadMessage(format!("missing integer field {key:?}")))
}

pub(crate) fn opt_u64(v: &Json, key: &str) -> Result<Option<u64>, WireError> {
    match v.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(x) => x
            .as_f64()
            .filter(|n| *n >= 0.0 && n.fract() == 0.0)
            .map(|n| Some(n as u64))
            .ok_or_else(|| WireError::BadMessage(format!("field {key:?} must be an integer"))),
    }
}

pub(crate) fn opt_usize(v: &Json, key: &str) -> Result<Option<usize>, WireError> {
    match v.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(x) => x
            .as_usize()
            .map(Some)
            .ok_or_else(|| WireError::BadMessage(format!("field {key:?} must be an integer"))),
    }
}

pub(crate) fn req_usize_arr(v: &Json, key: &str) -> Result<Vec<usize>, WireError> {
    v.get(key)
        .and_then(Json::as_arr)
        .and_then(|a| a.iter().map(Json::as_usize).collect::<Option<Vec<_>>>())
        .ok_or_else(|| WireError::BadMessage(format!("missing integer array {key:?}")))
}

pub(crate) fn opt_usize_arr(v: &Json, key: &str) -> Result<Option<Vec<usize>>, WireError> {
    match v.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(x) => x
            .as_arr()
            .and_then(|a| a.iter().map(Json::as_usize).collect::<Option<Vec<_>>>())
            .map(Some)
            .ok_or_else(|| {
                WireError::BadMessage(format!("field {key:?} must be an integer array"))
            }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frame_round_trips() {
        let msg = Json::obj(vec![("type", Json::from("ping")), ("n", Json::from(42usize))]);
        let bytes = encode_frame(&msg);
        let got = read_frame(&mut Cursor::new(bytes)).unwrap();
        assert_eq!(got, msg);
    }

    #[test]
    fn clean_eof_is_closed_not_torn() {
        let empty: &[u8] = &[];
        assert_eq!(read_frame(&mut Cursor::new(empty)), Err(WireError::Closed));
    }

    #[test]
    fn oversized_prefix_rejected_before_body() {
        let mut bytes = ((MAX_FRAME_BYTES + 1) as u32).to_be_bytes().to_vec();
        bytes.extend_from_slice(b"xx");
        let err = read_frame(&mut Cursor::new(bytes)).unwrap_err();
        assert!(matches!(err, WireError::Oversized { .. }));
    }

    #[test]
    fn base64_vectors() {
        assert_eq!(b64_encode(b""), "");
        assert_eq!(b64_encode(b"f"), "Zg==");
        assert_eq!(b64_encode(b"fo"), "Zm8=");
        assert_eq!(b64_encode(b"foo"), "Zm9v");
        assert_eq!(b64_decode("Zm9vYmFy").unwrap(), b"foobar");
        assert!(b64_decode("Zm9").is_err());
        assert!(b64_decode("Z=9v").is_err());
        assert!(b64_decode("Zm9!").is_err());
    }

    #[test]
    fn grid_payload_is_bit_exact() {
        let mut g = Grid::new2d(5, 7);
        g.fill_random(3, -10.0, 10.0);
        g.data_mut()[0] = f32::NAN;
        g.data_mut()[1] = f32::NEG_INFINITY;
        g.data_mut()[2] = -0.0;
        let p = GridPayload::from_grid(&g);
        let back = p.to_grid().unwrap();
        assert_eq!(back.dims(), g.dims());
        for (a, b) in back.data().iter().zip(g.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn f32_slab_codec_is_bit_exact_and_rejects_partial_cells() {
        let cells = [1.5f32, f32::NAN, -0.0, f32::INFINITY, 3.25e-12];
        let text = b64_encode_f32(&cells);
        let back = b64_decode_f32(&text).unwrap();
        assert_eq!(back.len(), cells.len());
        for (a, b) in back.iter().zip(&cells) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // 3 bytes decodes fine as base64 but is not a whole f32 cell.
        assert!(b64_decode_f32(&b64_encode(b"abc")).is_err());
    }
}
