//! The TCP front door: accepts wire tenants and multiplexes their jobs
//! onto an [`EngineServer`] so network clients and in-process
//! [`ClientSession`]s share one worker pool and one fairness discipline.
//!
//! Threading model (all std):
//!
//! - one **accept** thread; one **connection** thread per client socket
//!   (blocking reads with a short timeout so shutdown is prompt);
//! - one **reaper** thread that watches outstanding [`JobHandle`]s,
//!   records terminal transitions in the [`JobLedger`], runs the
//!   retry-with-max-attempts policy, and releases per-tenant quota.
//!
//! All mutable front-door state lives under ONE mutex (`Shared::state`);
//! the lock order is front-state → engine-state (via `ClientSession`
//! calls) → job-done, which is acyclic against the engine scheduler's own
//! engine-state → job-done order, so the combined system cannot deadlock.
//!
//! Sessions survive disconnects: a socket dying mid-job abandons nothing.
//! The tenant's jobs keep draining, and any connection may later poll or
//! fetch them by job id — that, plus journal replay in [`JobLedger`], is
//! what the kill-and-reconnect fault tests exercise. With checkpointing
//! on ([`WireConfig::checkpoint_every`]), jobs even survive process
//! death: `bind` replays the journal, finds each mid-flight job's
//! [`Checkpoint`] sidecar, and *resumes* it from the last grid barrier —
//! bit-identical to an uninterrupted run (DESIGN §3.4).

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::{ExecReport, Plan};
use crate::stencil::{Grid, StencilProgram, StencilRegistry};
use crate::util::json::Json;

use super::super::chaos::{ChaosCtx, ChaosPlan, FaultKind};
use super::super::server::{CheckpointSink, QUEUE_WAIT_BUCKETS};
use super::super::{Backend, ClientSession, EngineError, EngineServer, JobHandle, Workload};
use super::checkpoint::Checkpoint;
use super::protocol::{
    encode_frame, ErrorKind, GridPayload, PlanSpec, Request, Response, WireError,
    MAX_FRAME_BYTES,
};
use super::queue::{JobLedger, JobState, JobStatus};

/// How long a connection may dribble one frame's bytes before the read is
/// declared torn. Generous: a 64 MiB frame at 20 MB/s needs ~3.3 s.
const FRAME_DEADLINE: Duration = Duration::from_secs(30);

/// Poll interval for the first byte of a frame (bounds shutdown latency).
const IDLE_POLL: Duration = Duration::from_millis(100);

/// Front-door policy knobs. Defaults are deliberately modest — quotas are
/// the backpressure mechanism, so they should trip in tests long before
/// memory does.
#[derive(Debug, Clone)]
pub struct WireConfig {
    /// Per-tenant cap on jobs in flight (queued + active). Breaching it
    /// returns [`ErrorKind::QuotaJobs`] — backpressure, not failure.
    pub max_queued_jobs: usize,
    /// Per-tenant cap on total cells across jobs in flight
    /// ([`ErrorKind::QuotaCells`] beyond it).
    pub max_queued_cells: u64,
    /// Attempts (started) before a worker-side failure becomes terminal
    /// `Failed{attempts}`.
    pub max_attempts: u32,
    /// Append-only JSONL journal; replayed on bind so job ids and
    /// terminal statuses survive restarts. `None` = in-memory only.
    pub journal: Option<PathBuf>,
    /// Snapshot every job's grid to a [`Checkpoint`] sidecar each time
    /// this many iterations complete (at the next chunk barrier).
    /// Requires a journal; 0 = off.
    pub checkpoint_every: usize,
    /// Compact the journal on bind once it exceeds this many bytes
    /// (rewrite as one latest-state record per job). 0 = never.
    pub journal_rotate_bytes: u64,
    /// Seeded deterministic fault injection ([`ChaosPlan`]), threaded
    /// through tile execution, journal IO, checkpoint writes and
    /// connection handling. `None` = no faults.
    pub chaos: Option<Arc<ChaosPlan>>,
}

impl Default for WireConfig {
    fn default() -> WireConfig {
        WireConfig {
            max_queued_jobs: 8,
            max_queued_cells: 1 << 26,
            max_attempts: 3,
            journal: None,
            checkpoint_every: 0,
            journal_rotate_bytes: 1 << 20,
            chaos: None,
        }
    }
}

/// What we keep to resubmit a job on retry.
struct RetryInput {
    grid: Grid,
    power: Option<Grid>,
    iterations: Option<usize>,
    /// Iterations already baked into `grid` (non-zero for a job resumed
    /// from a checkpoint: the snapshot grid carries `base_iter` of the
    /// job's `total`).
    base_iter: usize,
    /// The job's total iteration count, checkpoint bookkeeping included.
    total: usize,
}

/// One wire job's front-door state. The ledger mirrors `state`; the
/// ledger is the durable record, this is the live machinery.
struct WireJob {
    tenant: u64,
    state: JobState,
    /// Attempts *started* (first submission counts as 1).
    attempts: u32,
    cells: u64,
    cancel_requested: bool,
    /// Absolute wall-clock deadline; retries get the remaining budget.
    deadline: Option<Instant>,
    handle: Option<JobHandle>,
    input: Option<RetryInput>,
    /// Held for exactly one fetch by a `wait` — then the state stays
    /// `Done` but later waits get a plain status.
    output: Option<(Grid, Json)>,
}

/// One wire tenant: an engine session plus quota and traffic accounting.
struct Tenant {
    client: ClientSession,
    /// The fully-resolved plan spec, embedded in checkpoints so a
    /// rebound frontend can rebuild this session without the original
    /// open request.
    spec: PlanSpec,
    outstanding_jobs: u64,
    outstanding_cells: u64,
    frames_in: u64,
    frames_out: u64,
    bytes_in: u64,
    bytes_out: u64,
}

struct FrontState {
    ledger: JobLedger,
    sessions: HashMap<u64, Tenant>,
    jobs: HashMap<u64, WireJob>,
    next_session: u64,
}

struct Shared {
    cfg: WireConfig,
    /// Taken (to `None`) at shutdown so the engine can be stopped by
    /// value; handlers only ever borrow it briefly to open sessions.
    engine: Mutex<Option<EngineServer>>,
    state: Mutex<FrontState>,
    /// Signals job transitions to server-side `wait`ers and the reaper.
    jobs_cv: Condvar,
    shutting: AtomicBool,
    conns: Mutex<Vec<JoinHandle<()>>>,
    /// Bind time, for the health check's uptime.
    started: Instant,
    /// Set by [`WireFrontend::kill`]: checkpoint sinks stop writing and
    /// terminal cleanup stops deleting sidecars, freezing the on-disk
    /// state at the "crash" instant. Shared with sink closures by `Arc`
    /// (not via `Arc<Shared>`, which would cycle through the engine).
    ckpt_frozen: Arc<AtomicBool>,
    /// Connection ids for the ConnDrop chaos key.
    conn_seq: AtomicU64,
}

/// The wire front door. Owns the [`EngineServer`] it fronts; dropping it
/// (or calling [`WireFrontend::shutdown`]) drains in-flight work, records
/// terminal ledger states, and joins every thread it spawned.
pub struct WireFrontend {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    reaper: Option<JoinHandle<()>>,
}

impl WireFrontend {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) in front of
    /// `server`. Replays the journal first when one is configured, so
    /// jobs interrupted by the previous run answer polls truthfully:
    /// each orphan with a valid [`Checkpoint`] sidecar is *resumed* from
    /// its last grid barrier (ledger records `Resumed{from_iter}`); the
    /// rest are healed to `Failed`. Oversized journals are compacted
    /// before serving.
    pub fn bind(
        addr: &str,
        server: EngineServer,
        cfg: WireConfig,
    ) -> std::io::Result<WireFrontend> {
        let ledger = match &cfg.journal {
            Some(path) => {
                let mut l = JobLedger::open_deferred(path)?;
                if let Some(ch) = &cfg.chaos {
                    l.set_chaos(Arc::clone(ch));
                }
                l
            }
            None => JobLedger::in_memory(),
        };
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let shared = Arc::new(Shared {
            cfg,
            engine: Mutex::new(Some(server)),
            state: Mutex::new(FrontState {
                ledger,
                sessions: HashMap::new(),
                jobs: HashMap::new(),
                next_session: 1,
            }),
            jobs_cv: Condvar::new(),
            shutting: AtomicBool::new(false),
            conns: Mutex::new(Vec::new()),
            started: Instant::now(),
            ckpt_frozen: Arc::new(AtomicBool::new(false)),
            conn_seq: AtomicU64::new(0),
        });
        // Orphan triage + housekeeping, all before any thread serves a
        // request, so clients only ever observe the settled ledger.
        {
            let mut st = shared.state.lock().expect("front state poisoned");
            if let Some(journal) = shared.cfg.journal.clone() {
                for id in st.ledger.orphans() {
                    if resume_orphan(&shared, &mut st, &journal, id).is_err() {
                        st.ledger.heal(id);
                        let _ =
                            std::fs::remove_file(Checkpoint::path_for(&journal, id));
                    }
                }
            }
            // Session ids must not collide with tenants replayed (and
            // possibly re-created, above) from the journal.
            let max_tenant = st.ledger.jobs().map(|s| s.tenant).max().unwrap_or(0);
            st.next_session = st.next_session.max(max_tenant + 1);
            let rotate = shared.cfg.journal_rotate_bytes;
            if rotate > 0 && st.ledger.journal_bytes() > rotate {
                let _ = st.ledger.compact();
            }
        }
        let accept_shared = Arc::clone(&shared);
        let accept =
            std::thread::spawn(move || accept_loop(&accept_shared, &listener));
        let reaper_shared = Arc::clone(&shared);
        let reaper = std::thread::spawn(move || reaper_loop(&reaper_shared));
        Ok(WireFrontend {
            shared,
            addr: local,
            accept: Some(accept),
            reaper: Some(reaper),
        })
    }

    /// The bound address (resolves `:0` to the ephemeral port picked).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Open an **in-process** session on the same engine the wire tenants
    /// use: both populations share one worker pool and one DRR fairness
    /// discipline — the multiplexing claim, as an API.
    pub fn open_local(&self, plan: Plan) -> Result<ClientSession, EngineError> {
        let guard = self.shared.engine.lock().expect("engine slot poisoned");
        match guard.as_ref() {
            Some(server) => server.open(plan),
            None => Err(EngineError::Shutdown),
        }
    }

    /// Job ids healed to `Failed` during journal replay (were mid-flight
    /// when the previous process died, with no usable checkpoint).
    pub fn healed_jobs(&self) -> Vec<u64> {
        self.shared.state.lock().expect("front state poisoned").ledger.healed.clone()
    }

    /// Jobs resumed from a checkpoint during journal replay:
    /// `(job, from_iter)` — the job restarted with `from_iter` of its
    /// iterations already done.
    pub fn resumed_jobs(&self) -> Vec<(u64, usize)> {
        self.shared.state.lock().expect("front state poisoned").ledger.resumed.clone()
    }

    /// Crash simulation (tests): freeze the journal and every checkpoint
    /// sidecar at this instant — no further journal appends, checkpoint
    /// writes or sidecar deletions — then tear down threads. The on-disk
    /// state is exactly what a SIGKILL at this point would have left, so
    /// a subsequent [`WireFrontend::bind`] exercises the real
    /// resume-or-heal path.
    pub fn kill(&mut self) {
        self.shared.ckpt_frozen.store(true, Ordering::SeqCst);
        self.shared.state.lock().expect("front state poisoned").ledger.freeze();
        self.shutdown();
    }

    /// Latest ledger status of a job (ops/test introspection; the wire
    /// `poll` request is the protocol-level equivalent).
    pub fn job_status(&self, job: u64) -> Option<JobStatus> {
        self.shared
            .state
            .lock()
            .expect("front state poisoned")
            .ledger
            .status(job)
            .cloned()
    }

    /// Graceful shutdown: stop accepting, join connections, stop the
    /// engine (which completes every outstanding handle), let the reaper
    /// drain those completions into terminal ledger states, then join it.
    /// Idempotent; runs on drop.
    pub fn shutdown(&mut self) {
        if self.shared.shutting.swap(true, Ordering::SeqCst) {
            // Another call already ran the sequence; just reap handles.
            if let Some(h) = self.accept.take() {
                let _ = h.join();
            }
            if let Some(h) = self.reaper.take() {
                let _ = h.join();
            }
            return;
        }
        // Wake the blocking accept() with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let conns: Vec<JoinHandle<()>> = {
            let mut guard = self.shared.conns.lock().expect("conns poisoned");
            guard.drain(..).collect()
        };
        for c in conns {
            let _ = c.join();
        }
        if let Some(mut server) =
            self.shared.engine.lock().expect("engine slot poisoned").take()
        {
            server.shutdown();
        }
        self.shared.jobs_cv.notify_all();
        if let Some(h) = self.reaper.take() {
            let _ = h.join();
        }
    }
}

impl Drop for WireFrontend {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ----------------------------------------------------------- accept loop

fn accept_loop(shared: &Arc<Shared>, listener: &TcpListener) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if shared.shutting.load(Ordering::SeqCst) {
                    return;
                }
                let conn_shared = Arc::clone(shared);
                let handle =
                    std::thread::spawn(move || connection_loop(&conn_shared, stream));
                let mut conns = shared.conns.lock().expect("conns poisoned");
                conns.retain(|c| !c.is_finished());
                conns.push(handle);
            }
            Err(_) => {
                if shared.shutting.load(Ordering::SeqCst) {
                    return;
                }
                // Transient accept failure (fd pressure); back off briefly.
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
}

// ------------------------------------------------------ connection loop

/// Read one frame, shutdown-aware. The FIRST byte is polled with a short
/// timeout (checking the shutting flag between polls); once a frame has
/// started, the rest of the header and body are read under a deadline —
/// so a slow-but-live client streaming a megabyte grid is never cut off,
/// while a wedged peer cannot pin the thread past [`FRAME_DEADLINE`].
/// Returns `Ok(None)` when the server is shutting down.
fn read_frame_patient(
    stream: &mut TcpStream,
    shutting: &AtomicBool,
) -> Result<Option<Json>, WireError> {
    let mut first = [0u8; 1];
    loop {
        if shutting.load(Ordering::SeqCst) {
            return Ok(None);
        }
        match stream.read(&mut first) {
            Ok(0) => return Err(WireError::Closed),
            Ok(_) => break,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                continue;
            }
            Err(e) => return Err(e.into()),
        }
    }
    let deadline = Instant::now() + FRAME_DEADLINE;
    let mut header = [0u8; 4];
    header[0] = first[0];
    read_deadline(stream, &mut header[1..], deadline, 4, shutting)?;
    let len = u32::from_be_bytes(header) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(WireError::Oversized { len, max: MAX_FRAME_BYTES });
    }
    let mut body = vec![0u8; len];
    read_deadline(stream, &mut body, deadline, len, shutting)?;
    let text = String::from_utf8(body)
        .map_err(|e| WireError::BadJson(format!("invalid utf-8: {e}")))?;
    Json::parse(&text)
        .map(Some)
        .map_err(|e| WireError::BadJson(e.to_string()))
}

/// Deadline-bounded `read_exact`. Also aborts mid-frame on shutdown —
/// the server is going down and the submit would be rejected anyway, so
/// bounded shutdown latency wins over finishing the transfer.
fn read_deadline(
    stream: &mut TcpStream,
    buf: &mut [u8],
    deadline: Instant,
    want: usize,
    shutting: &AtomicBool,
) -> Result<(), WireError> {
    let mut got = 0;
    while got < buf.len() {
        if Instant::now() >= deadline || shutting.load(Ordering::SeqCst) {
            return Err(WireError::Torn { got, want });
        }
        match stream.read(&mut buf[got..]) {
            Ok(0) => return Err(WireError::Torn { got, want }),
            Ok(n) => got += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                continue;
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

fn send_response(stream: &mut TcpStream, resp: &Response) -> bool {
    let frame = encode_frame(&resp.to_json());
    stream.write_all(&frame).and_then(|()| stream.flush()).is_ok()
}

fn connection_loop(shared: &Arc<Shared>, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(IDLE_POLL));
    let conn = shared.conn_seq.fetch_add(1, Ordering::SeqCst);
    let mut frame_i: u64 = 0;
    loop {
        match read_frame_patient(&mut stream, &shared.shutting) {
            Ok(None) | Err(WireError::Closed) => return,
            Ok(Some(msg)) => {
                frame_i += 1;
                // Body length approximated by re-serialization (byte-
                // identical for frames our own client sends), +4 header.
                let in_bytes = msg.to_string().len() as u64 + 4;
                let (resp, tenant) = handle_frame(shared, &msg);
                let frame = encode_frame(&resp.to_json());
                attribute_traffic(shared, tenant, in_bytes, frame.len() as u64);
                if stream.write_all(&frame).and_then(|()| stream.flush()).is_err() {
                    return;
                }
                // Chaos: sever the connection after the response. The
                // session and its jobs survive — exactly the disconnect
                // resilience the reconnect tests assert.
                if let Some(ch) = &shared.cfg.chaos {
                    if ch.should(FaultKind::ConnDrop, conn, 0, frame_i) {
                        return;
                    }
                }
            }
            Err(WireError::BadJson(m)) => {
                // Frame length was honored, so the stream is still in
                // sync — report the garbage and keep serving.
                let ok = send_response(
                    &mut stream,
                    &Response::Error { kind: ErrorKind::BadFrame, message: m },
                );
                if !ok {
                    return;
                }
            }
            Err(e @ WireError::Oversized { .. }) => {
                // Body unread → framing is lost; answer, then hang up.
                let _ = send_response(
                    &mut stream,
                    &Response::Error { kind: ErrorKind::BadFrame, message: e.to_string() },
                );
                return;
            }
            // Torn frame or transport error: the byte stream can no
            // longer be trusted. Drop the connection; the session and
            // its jobs survive for the next connection to pick up.
            Err(_) => return,
        }
    }
}

fn attribute_traffic(shared: &Arc<Shared>, tenant: Option<u64>, inb: u64, outb: u64) {
    let Some(id) = tenant else { return };
    let mut st = shared.state.lock().expect("front state poisoned");
    if let Some(t) = st.sessions.get_mut(&id) {
        t.frames_in += 1;
        t.frames_out += 1;
        t.bytes_in += inb;
        t.bytes_out += outb;
    }
}

// -------------------------------------------------------- frame handling

/// Decode and dispatch one request. Returns the response plus the tenant
/// the traffic should be attributed to (if the request named one).
fn handle_frame(shared: &Arc<Shared>, msg: &Json) -> (Response, Option<u64>) {
    let req = match Request::from_json(msg) {
        Ok(r) => r,
        Err(e) => {
            return (
                Response::Error { kind: ErrorKind::BadRequest, message: e.to_string() },
                None,
            )
        }
    };
    match req {
        Request::Ping => (handle_ping(shared), None),
        Request::Open { plan, programs } => handle_open(shared, &plan, &programs),
        Request::Submit { session, grid, power, iterations, deadline_ms } => (
            handle_submit(shared, session, &grid, power.as_ref(), iterations, deadline_ms),
            Some(session),
        ),
        Request::Poll { job } => {
            let st = shared.state.lock().expect("front state poisoned");
            let tenant = st.ledger.status(job).map(|s| s.tenant);
            (status_response(&st, job), tenant)
        }
        Request::Wait { job, timeout_ms } => handle_wait(shared, job, timeout_ms),
        Request::Cancel { job } => handle_cancel(shared, job),
        Request::Stats { session } => (handle_stats(shared, session), Some(session)),
        Request::Close { session } => {
            let mut st = shared.state.lock().expect("front state poisoned");
            match st.sessions.remove(&session) {
                // Dropping the Tenant drops its ClientSession: the engine
                // marks the slot closed and reaps it once queued jobs
                // drain. Outstanding wire jobs stay poll-able by id.
                Some(_) => (Response::Closed { session }, None),
                None => (
                    Response::Error {
                        kind: ErrorKind::UnknownSession,
                        message: format!("no session {session}"),
                    },
                    None,
                ),
            }
        }
    }
}

/// Liveness probe, now a health check: uptime, pool size, live job
/// counts and whether chaos injection is armed. Lock order: front-state
/// is taken and released before the engine slot — never nested.
fn handle_ping(shared: &Arc<Shared>) -> Response {
    let (jobs_queued, jobs_active) = {
        let st = shared.state.lock().expect("front state poisoned");
        let mut queued = 0u64;
        let mut active = 0u64;
        for j in st.jobs.values() {
            match j.state {
                JobState::Queued => queued += 1,
                JobState::Active | JobState::Resumed { .. } => active += 1,
                _ => {}
            }
        }
        (queued, active)
    };
    let workers = {
        let guard = shared.engine.lock().expect("engine slot poisoned");
        guard.as_ref().map(EngineServer::workers).unwrap_or(0)
    };
    Response::Pong {
        uptime_ms: shared.started.elapsed().as_millis() as u64,
        workers: workers as u64,
        jobs_queued,
        jobs_active,
        chaos: shared.cfg.chaos.is_some(),
    }
}

fn handle_open(
    shared: &Arc<Shared>,
    spec: &PlanSpec,
    programs: &[Json],
) -> (Response, Option<u64>) {
    if shared.shutting.load(Ordering::SeqCst) {
        return (shutting_error(), None);
    }
    // Inline programs first (registration is idempotent-by-content), so
    // the plan spec can reference stencils defined in the same request.
    for p in programs {
        let program = match StencilProgram::from_json(p) {
            Ok(prog) => prog,
            Err(e) => {
                return (
                    Response::Error {
                        kind: ErrorKind::Plan,
                        message: format!("bad inline stencil program: {e:#}"),
                    },
                    None,
                )
            }
        };
        if let Err(e) = StencilRegistry::register(program) {
            return (
                Response::Error {
                    kind: ErrorKind::Plan,
                    message: format!("stencil registration failed: {e:#}"),
                },
                None,
            );
        }
    }
    let plan = match spec.build() {
        Ok(p) => p,
        Err(e) => {
            // Prefer the auditor's structured diagnostics over the
            // builder's single message: a spec the builder refuses
            // (halo-swallowed tile, unschedulable iterations, ...) comes
            // back as a typed report the client can render field by field.
            if let Some(report) = audit_spec(spec) {
                return (
                    Response::Rejected {
                        message: EngineError::Rejected(report.clone()).to_string(),
                        diagnostics: report.to_json(),
                    },
                    None,
                );
            }
            return (
                Response::Error { kind: ErrorKind::Plan, message: e.to_string() },
                None,
            );
        }
    };
    // The fully-resolved spec (defaults filled in by the builder) is what
    // checkpoints embed — it must rebuild this exact plan after restart.
    let full_spec = PlanSpec::from_plan(&plan);
    // Engine session queue depth exceeds the wire quota, so a quota-
    // admitted submit can never block on engine backpressure while the
    // front-state lock is held (quota is checked under that lock first).
    let depth = shared.cfg.max_queued_jobs.max(1) + 1;
    let client = {
        let guard = shared.engine.lock().expect("engine slot poisoned");
        match guard.as_ref() {
            Some(server) => server.open_with_queue(plan, depth),
            None => Err(EngineError::Shutdown),
        }
    };
    let client = match client {
        Ok(c) => c,
        Err(e) => return (engine_error(&e), None),
    };
    let mut st = shared.state.lock().expect("front state poisoned");
    let session = st.next_session;
    st.next_session += 1;
    st.sessions.insert(
        session,
        Tenant {
            client,
            spec: full_spec,
            outstanding_jobs: 0,
            outstanding_cells: 0,
            frames_in: 0,
            frames_out: 0,
            bytes_in: 0,
            bytes_out: 0,
        },
    );
    (Response::Opened { session }, Some(session))
}

fn handle_submit(
    shared: &Arc<Shared>,
    session: u64,
    grid: &GridPayload,
    power: Option<&GridPayload>,
    iterations: Option<usize>,
    deadline_ms: Option<u64>,
) -> Response {
    if shared.shutting.load(Ordering::SeqCst) {
        return shutting_error();
    }
    // Decode payloads before taking any lock — base64 of a big grid is
    // real CPU work and needs no shared state.
    let grid = match grid.to_grid() {
        Ok(g) => g,
        Err(e) => {
            return Response::Error { kind: ErrorKind::BadRequest, message: e.to_string() }
        }
    };
    let power = match power.map(GridPayload::to_grid).transpose() {
        Ok(p) => p,
        Err(e) => {
            return Response::Error { kind: ErrorKind::BadRequest, message: e.to_string() }
        }
    };
    let cells = grid.len() as u64;

    let mut st = shared.state.lock().expect("front state poisoned");
    let Some(tenant) = st.sessions.get(&session) else {
        return Response::Error {
            kind: ErrorKind::UnknownSession,
            message: format!("no session {session}"),
        };
    };
    // Quotas are the typed-backpressure surface: the client is told to
    // drain, nothing is charged, and other tenants are untouched.
    if tenant.outstanding_jobs >= shared.cfg.max_queued_jobs as u64 {
        return Response::Error {
            kind: ErrorKind::QuotaJobs,
            message: format!(
                "tenant has {} jobs in flight (quota {})",
                tenant.outstanding_jobs, shared.cfg.max_queued_jobs
            ),
        };
    }
    if tenant.outstanding_cells + cells > shared.cfg.max_queued_cells {
        return Response::Error {
            kind: ErrorKind::QuotaCells,
            message: format!(
                "tenant has {} cells in flight; {} more exceeds the {}-cell quota",
                tenant.outstanding_cells, cells, shared.cfg.max_queued_cells
            ),
        };
    }
    // The job's total iteration count: the per-submit override, else the
    // tenant plan's default. Checkpoints track progress against this.
    let total = iterations.unwrap_or(tenant.spec.iterations);
    let spec = tenant.spec.clone();
    let mut workload = Workload::new(grid.clone());
    if let Some(p) = &power {
        workload = workload.power(p.clone());
    }
    if let Some(i) = iterations {
        workload = workload.iterations(i);
    }
    let deadline = deadline_ms.map(Duration::from_millis);
    if let Some(d) = deadline {
        workload = workload.deadline(d);
    }
    // Allocate the id before the engine sees the job so the checkpoint
    // sink can be keyed on it. A submit the engine then rejects burns the
    // id — harmless, nothing was recorded under it.
    let job = st.ledger.allocate();
    let workload =
        arm_workload(shared, workload, job, session, 1, &spec, power.as_ref(), total, 0);
    // Never blocks: quota admitted < engine queue depth (see handle_open).
    let tenant = st.sessions.get(&session).expect("tenant checked above");
    let handle = match tenant.client.submit(workload) {
        Ok(h) => h,
        // Validation failed — nothing was accepted, charge nothing.
        Err(e) => return engine_error(&e),
    };
    st.ledger.record(JobStatus {
        job,
        tenant: session,
        state: JobState::Queued,
        attempts: 0,
        cells,
    });
    st.ledger.record(JobStatus {
        job,
        tenant: session,
        state: JobState::Active,
        attempts: 1,
        cells,
    });
    st.jobs.insert(
        job,
        WireJob {
            tenant: session,
            state: JobState::Active,
            attempts: 1,
            cells,
            cancel_requested: false,
            deadline: deadline.map(|d| Instant::now() + d),
            handle: Some(handle),
            input: Some(RetryInput { grid, power, iterations, base_iter: 0, total }),
            output: None,
        },
    );
    let t = st.sessions.get_mut(&session).expect("tenant checked above");
    t.outstanding_jobs += 1;
    t.outstanding_cells += cells;
    shared.jobs_cv.notify_all();
    Response::Accepted { job }
}

/// Status snapshot from the ledger — answers for live jobs, finished
/// jobs, and jobs replayed from a previous process alike.
fn status_response(st: &FrontState, job: u64) -> Response {
    match st.ledger.status(job) {
        Some(s) => Response::Status { job, state: s.state.clone(), attempts: s.attempts },
        None => Response::Error {
            kind: ErrorKind::UnknownJob,
            message: format!("no job {job}"),
        },
    }
}

fn handle_wait(shared: &Arc<Shared>, job: u64, timeout_ms: u64) -> (Response, Option<u64>) {
    let deadline = Instant::now() + Duration::from_millis(timeout_ms);
    let mut st = shared.state.lock().expect("front state poisoned");
    let tenant = st.ledger.status(job).map(|s| s.tenant);
    loop {
        let Some(status) = st.ledger.status(job) else {
            return (
                Response::Error {
                    kind: ErrorKind::UnknownJob,
                    message: format!("no job {job}"),
                },
                None,
            );
        };
        if status.state.is_terminal() {
            let attempts = status.attempts;
            if status.state == JobState::Done {
                // The result is fetched-once: the first wait carries the
                // grid home and frees the buffer; later waits (and any
                // poll) see a plain Done status.
                if let Some((grid, report)) =
                    st.jobs.get_mut(&job).and_then(|j| j.output.take())
                {
                    return (
                        Response::Result {
                            job,
                            grid: GridPayload::from_grid(&grid),
                            attempts,
                            report,
                        },
                        tenant,
                    );
                }
            }
            return (status_response(&st, job), tenant);
        }
        let now = Instant::now();
        if now >= deadline || shared.shutting.load(Ordering::SeqCst) {
            return (status_response(&st, job), tenant);
        }
        // Short slices keep shutdown latency bounded even if a notify
        // is lost to a race.
        let slice = (deadline - now).min(Duration::from_millis(50));
        st = shared
            .jobs_cv
            .wait_timeout(st, slice)
            .expect("front state poisoned")
            .0;
    }
}

fn handle_cancel(shared: &Arc<Shared>, job: u64) -> (Response, Option<u64>) {
    let mut st = shared.state.lock().expect("front state poisoned");
    let tenant = st.ledger.status(job).map(|s| s.tenant);
    if tenant.is_none() {
        return (
            Response::Error { kind: ErrorKind::UnknownJob, message: format!("no job {job}") },
            None,
        );
    }
    if let Some(j) = st.jobs.get_mut(&job) {
        if !j.state.is_terminal() {
            j.cancel_requested = true;
            if let Some(h) = &j.handle {
                h.cancel();
            }
            shared.jobs_cv.notify_all();
        }
    }
    // Idempotent ack: current status (the reaper records Cancelled once
    // the engine confirms; a completion that wins the race stands).
    (status_response(&st, job), tenant)
}

fn handle_stats(shared: &Arc<Shared>, session: u64) -> Response {
    let st = shared.state.lock().expect("front state poisoned");
    let Some(t) = st.sessions.get(&session) else {
        return Response::Error {
            kind: ErrorKind::UnknownSession,
            message: format!("no session {session}"),
        };
    };
    let es = t.client.stats();
    let hist: Vec<Json> =
        (0..QUEUE_WAIT_BUCKETS).map(|i| Json::from(es.queue_wait_hist[i] as usize)).collect();
    let engine = Json::obj(vec![
        ("jobs_submitted", Json::from(es.jobs_submitted as usize)),
        ("jobs_completed", Json::from(es.jobs_completed as usize)),
        ("jobs_cancelled", Json::from(es.jobs_cancelled as usize)),
        ("jobs_failed", Json::from(es.jobs_failed as usize)),
        ("tiles_executed", Json::from(es.tiles_executed as usize)),
        ("nonfinite_trips", Json::from(es.nonfinite_trips as usize)),
        ("cell_updates", Json::from(es.cell_updates as usize)),
        ("max_queue_wait_us", Json::from(es.max_queue_wait.as_micros() as usize)),
        ("sched_served", Json::from(es.sched_served as usize)),
        ("sched_rounds", Json::from(es.sched_rounds as usize)),
        // Bucket i counts dispatches whose submit→dispatch wait fell in
        // [2^i, 2^(i+1)) microseconds; the last bucket absorbs the tail.
        ("queue_wait_hist_us_pow2", Json::Arr(hist)),
    ]);
    let wire = Json::obj(vec![
        ("frames_in", Json::from(t.frames_in as usize)),
        ("frames_out", Json::from(t.frames_out as usize)),
        ("bytes_in", Json::from(t.bytes_in as usize)),
        ("bytes_out", Json::from(t.bytes_out as usize)),
        ("outstanding_jobs", Json::from(t.outstanding_jobs as usize)),
        ("outstanding_cells", Json::from(t.outstanding_cells as usize)),
    ]);
    Response::Stats {
        session,
        stats: Json::obj(vec![("engine", engine), ("wire", wire)]),
    }
}

fn shutting_error() -> Response {
    Response::Error {
        kind: ErrorKind::Shutdown,
        message: "server is shutting down".to_string(),
    }
}

/// Best-effort audit of a spec the builder refused: resolve the stencil
/// and backend if possible (otherwise there is nothing to audit), fill
/// the builder's defaults, and return the report iff it carries the
/// Error-level findings that explain the refusal.
fn audit_spec(spec: &PlanSpec) -> Option<crate::analysis::AuditReport> {
    let id = StencilRegistry::lookup(&spec.stencil)?;
    let backend = Backend::parse(&spec.backend).ok()?;
    let mut shape =
        crate::analysis::PlanShape::with_defaults(id, spec.grid_dims.clone(), spec.iterations);
    shape.backend = backend;
    if let Some(t) = &spec.tile {
        shape.tile = t.clone();
    }
    if let Some(c) = &spec.coeffs {
        shape.coeffs = c.clone();
    }
    if let Some(s) = &spec.step_sizes {
        shape.step_sizes = s.clone();
    }
    shape.workers = spec.workers;
    shape.guard_nonfinite = spec.guard_nonfinite.unwrap_or(false);
    let report = crate::analysis::audit_shape(&shape);
    report.has_errors().then_some(report)
}

fn engine_error(e: &EngineError) -> Response {
    let kind = match e {
        // A static-audit rejection carries its full report so the client
        // sees every diagnostic, not one flattened string.
        EngineError::Rejected(report) => {
            return Response::Rejected {
                message: e.to_string(),
                diagnostics: report.to_json(),
            };
        }
        EngineError::Shutdown => ErrorKind::Shutdown,
        EngineError::DeadlineExceeded => ErrorKind::DeadlineExceeded,
        _ => ErrorKind::Engine,
    };
    Response::Error { kind, message: e.to_string() }
}

// ------------------------------------------------- crash safety plumbing

/// Attach the crash-safety machinery to one engine submission: the chaos
/// context (so tile faults key on the *wire* job id and attempt) and,
/// when checkpointing is on, a self-contained snapshot sink.
///
/// The sink runs on the engine scheduler thread, so it must not touch
/// `Shared::state` (lock order: front-state → engine-state; the scheduler
/// holds engine-state). Everything it needs is captured by value, plus
/// the frozen flag by `Arc`.
#[allow(clippy::too_many_arguments)]
fn arm_workload(
    shared: &Arc<Shared>,
    mut w: Workload,
    job: u64,
    tenant: u64,
    attempt: u32,
    spec: &PlanSpec,
    power: Option<&Grid>,
    total: usize,
    base: usize,
) -> Workload {
    if let Some(ch) = &shared.cfg.chaos {
        w = w.chaos(ChaosCtx { plan: Arc::clone(ch), job, attempt });
    }
    let every = shared.cfg.checkpoint_every;
    if every == 0 {
        return w;
    }
    let Some(journal) = shared.cfg.journal.clone() else { return w };
    let path = Checkpoint::path_for(&journal, job);
    let plan_spec = spec.clone();
    let power_payload = power.map(GridPayload::from_grid);
    let chaos = shared.cfg.chaos.clone();
    let frozen = Arc::clone(&shared.ckpt_frozen);
    let sink: CheckpointSink = Arc::new(move |iters_done: usize, grid: &Grid| {
        if frozen.load(Ordering::SeqCst) {
            return;
        }
        let done = base + iters_done;
        let ck = Checkpoint {
            job,
            tenant,
            attempt,
            total,
            done,
            plan: plan_spec.clone(),
            grid: GridPayload::from_grid(grid),
            power: power_payload.clone(),
        };
        let corrupt = chaos
            .as_ref()
            .is_some_and(|c| c.should(FaultKind::CheckpointCorrupt, job, attempt, done as u64));
        // Best-effort: a failed snapshot only costs resume granularity.
        let _ = ck.save(&path, corrupt);
    });
    w.checkpoint(every, sink)
}

/// Try to resume one journal orphan from its checkpoint sidecar. Any
/// `Err` sends the caller down the heal path — a torn/corrupt/stale
/// sidecar must degrade to the pre-checkpoint behavior, never resume
/// from poison. On success the job is live again: ledger shows
/// `Resumed{from_iter}`, the engine is running `total - done` iterations
/// from the snapshot grid, and the result is bit-identical to an
/// uninterrupted run (greedy-schedule suffix property, DESIGN §3.4).
fn resume_orphan(
    shared: &Arc<Shared>,
    st: &mut FrontState,
    journal: &Path,
    id: u64,
) -> Result<(), String> {
    let ck = Checkpoint::load(&Checkpoint::path_for(journal, id))?;
    if ck.job != id {
        return Err(format!("sidecar names job {}, expected {id}", ck.job));
    }
    if ck.done == 0 || ck.done >= ck.total {
        return Err(format!(
            "checkpoint at {}/{} iterations is not resumable",
            ck.done, ck.total
        ));
    }
    let prev =
        st.ledger.status(id).cloned().ok_or_else(|| "job not in ledger".to_string())?;
    if prev.tenant != ck.tenant {
        return Err(format!(
            "sidecar names tenant {}, journal says {}",
            ck.tenant, prev.tenant
        ));
    }
    let grid = ck.grid.to_grid().map_err(|e| e.to_string())?;
    let power =
        ck.power.as_ref().map(GridPayload::to_grid).transpose().map_err(|e| e.to_string())?;
    // Recreate the owning tenant session if the restart lost it. Inline
    // stencil programs die with the process registry, so a plan built on
    // one fails here and the job heals — the documented degradation.
    if !st.sessions.contains_key(&ck.tenant) {
        let plan = ck.plan.build().map_err(|e| e.to_string())?;
        let depth = shared.cfg.max_queued_jobs.max(1) + 1;
        let client = {
            let guard = shared.engine.lock().expect("engine slot poisoned");
            match guard.as_ref() {
                Some(server) => {
                    server.open_with_queue(plan, depth).map_err(|e| e.to_string())?
                }
                None => return Err("engine is shut down".to_string()),
            }
        };
        st.sessions.insert(
            ck.tenant,
            Tenant {
                client,
                spec: ck.plan.clone(),
                outstanding_jobs: 0,
                outstanding_cells: 0,
                frames_in: 0,
                frames_out: 0,
                bytes_in: 0,
                bytes_out: 0,
            },
        );
    }
    let attempts = prev.attempts + 1;
    let cells = grid.len() as u64;
    let remaining = ck.total - ck.done;
    let mut w = Workload::new(grid.clone()).iterations(remaining);
    if let Some(p) = &power {
        w = w.power(p.clone());
    }
    w = arm_workload(
        shared,
        w,
        id,
        ck.tenant,
        attempts,
        &ck.plan,
        power.as_ref(),
        ck.total,
        ck.done,
    );
    let tenant = st.sessions.get(&ck.tenant).expect("tenant ensured above");
    let handle = tenant.client.submit(w).map_err(|e| e.to_string())?;
    st.ledger.mark_resumed(id, ck.done, attempts);
    st.jobs.insert(
        id,
        WireJob {
            tenant: ck.tenant,
            state: JobState::Resumed { from_iter: ck.done },
            attempts,
            cells,
            cancel_requested: false,
            deadline: None,
            handle: Some(handle),
            input: Some(RetryInput {
                grid,
                power,
                iterations: Some(remaining),
                base_iter: ck.done,
                total: ck.total,
            }),
            output: None,
        },
    );
    let t = st.sessions.get_mut(&ck.tenant).expect("tenant ensured above");
    t.outstanding_jobs += 1;
    t.outstanding_cells += cells;
    Ok(())
}

// ---------------------------------------------------------------- reaper

fn report_json(report: &ExecReport) -> Json {
    Json::obj(vec![
        ("iterations", Json::from(report.iterations)),
        ("passes", Json::from(report.passes)),
        ("tiles_executed", Json::from(report.tiles_executed as usize)),
        ("cell_updates", Json::from(report.cell_updates as usize)),
        ("redundant_updates", Json::from(report.redundant_updates as usize)),
        ("elapsed_ms", Json::from(report.elapsed.as_secs_f64() * 1e3)),
        ("backend", Json::from(report.backend)),
    ])
}

/// Watches outstanding handles; on completion applies the
/// retry/cancel/ledger policy. Single consumer of handle results, so
/// every transition is serialized through the front-state lock.
fn reaper_loop(shared: &Arc<Shared>) {
    loop {
        let mut st = shared.state.lock().expect("front state poisoned");
        let finished: Vec<u64> = st
            .jobs
            .iter()
            .filter(|(_, j)| j.handle.as_ref().is_some_and(JobHandle::is_done))
            .map(|(&id, _)| id)
            .collect();
        for id in finished {
            let Some(handle) = st.jobs.get_mut(&id).and_then(|j| j.handle.take())
            else {
                continue;
            };
            // is_done() was true, so this returns without blocking.
            let result = handle.wait();
            resolve(shared, &mut st, id, result);
        }
        if !st.jobs.values().any(|j| j.handle.is_some())
            && shared.shutting.load(Ordering::SeqCst)
        {
            return;
        }
        let poll = if st.jobs.values().any(|j| j.handle.is_some()) {
            Duration::from_millis(2)
        } else {
            Duration::from_millis(200)
        };
        let _ = shared
            .jobs_cv
            .wait_timeout(st, poll)
            .expect("front state poisoned");
    }
}

/// What one completed attempt amounted to, snapshotted so no job borrow
/// survives into the state transitions below.
enum Outcome {
    Done(super::super::JobOutput),
    Cancelled,
    Shutdown,
    /// The deadline passed — terminal immediately, never retried (a
    /// retry could not finish any sooner than the attempt that expired).
    Deadline,
    Fail(String),
}

/// Apply one completed attempt's outcome. Precedence: a requested cancel
/// beats both failure and shutdown (the tenant asked for the job to stop;
/// how it stopped is incidental) — mirroring the engine-side
/// cancelled-then-shutdown fix in `server.rs`.
fn resolve(
    shared: &Arc<Shared>,
    st: &mut FrontState,
    id: u64,
    result: Result<super::super::JobOutput, EngineError>,
) {
    let cfg = &shared.cfg;
    let (attempts, cancel_requested) = {
        let job = st.jobs.get(&id).expect("resolving a known job");
        (job.attempts, job.cancel_requested)
    };
    let outcome = match result {
        Ok(out) => Outcome::Done(out),
        Err(EngineError::Cancelled) => Outcome::Cancelled,
        Err(EngineError::Shutdown) => Outcome::Shutdown,
        Err(EngineError::DeadlineExceeded) => Outcome::Deadline,
        Err(e) => Outcome::Fail(e.to_string()),
    };
    match outcome {
        Outcome::Done(out) => {
            let job = st.jobs.get_mut(&id).expect("resolving a known job");
            job.output = Some((out.grid, report_json(&out.report)));
            finish(shared, st, id, JobState::Done);
        }
        Outcome::Cancelled => finish(shared, st, id, JobState::Cancelled),
        Outcome::Shutdown => {
            let state = if cancel_requested {
                JobState::Cancelled
            } else {
                JobState::Failed {
                    attempts,
                    error: "server shutdown before the job finished".to_string(),
                }
            };
            finish(shared, st, id, state);
        }
        Outcome::Deadline => {
            let state = if cancel_requested {
                JobState::Cancelled
            } else {
                JobState::Failed {
                    attempts,
                    error: "deadline-exceeded: the job's deadline passed before it \
                            finished"
                        .to_string(),
                }
            };
            finish(shared, st, id, state);
        }
        Outcome::Fail(_) if cancel_requested => {
            finish(shared, st, id, JobState::Cancelled);
        }
        Outcome::Fail(error) if attempts < cfg.max_attempts => {
            retry(shared, st, id, &error);
        }
        Outcome::Fail(error) => {
            finish(shared, st, id, JobState::Failed { attempts, error });
        }
    }
}

/// Record a terminal state, release the tenant's quota, wake waiters.
/// The checkpoint sidecar is deleted — unless [`WireFrontend::kill`]
/// froze the on-disk state, in which case the crash snapshot stands.
fn finish(shared: &Arc<Shared>, st: &mut FrontState, id: u64, state: JobState) {
    let FrontState { ledger, sessions, jobs, .. } = st;
    let job = jobs.get_mut(&id).expect("finishing a known job");
    job.state = state.clone();
    job.input = None;
    if state != JobState::Done {
        job.output = None;
    }
    ledger.record(JobStatus {
        job: id,
        tenant: job.tenant,
        state,
        attempts: job.attempts,
        cells: job.cells,
    });
    // The tenant may have closed its session while the job drained.
    if let Some(t) = sessions.get_mut(&job.tenant) {
        t.outstanding_jobs = t.outstanding_jobs.saturating_sub(1);
        t.outstanding_cells = t.outstanding_cells.saturating_sub(job.cells);
    }
    if let Some(journal) = &shared.cfg.journal {
        if !shared.ckpt_frozen.load(Ordering::SeqCst) {
            let _ = std::fs::remove_file(Checkpoint::path_for(journal, id));
        }
    }
    shared.jobs_cv.notify_all();
}

/// Re-submit a failed attempt through the tenant's engine session. The
/// journal shows the full cycle: Queued(k) when the attempt fails,
/// Active(k+1) when the next one starts.
fn retry(shared: &Arc<Shared>, st: &mut FrontState, id: u64, error: &str) {
    let FrontState { ledger, sessions, jobs, .. } = st;
    let job = jobs.get_mut(&id).expect("retrying a known job");
    let (tenant_alive, resubmitted) = match sessions.get(&job.tenant) {
        None => (false, Err(EngineError::Shutdown)),
        Some(t) => {
            let input = job.input.as_ref().expect("retryable job keeps its input");
            let mut w = Workload::new(input.grid.clone());
            if let Some(p) = &input.power {
                w = w.power(p.clone());
            }
            if let Some(i) = input.iterations {
                w = w.iterations(i);
            }
            if let Some(d) = job.deadline {
                // The remaining budget only; an already-expired deadline
                // becomes a zero budget and fails fast in the engine's
                // queued-deadline sweep.
                w = w.deadline(d.saturating_duration_since(Instant::now()));
            }
            let w = arm_workload(
                shared,
                w,
                id,
                job.tenant,
                job.attempts + 1,
                &t.spec,
                input.power.as_ref(),
                input.total,
                input.base_iter,
            );
            (true, t.client.submit(w))
        }
    };
    match resubmitted {
        Ok(handle) => {
            ledger.record(JobStatus {
                job: id,
                tenant: job.tenant,
                state: JobState::Queued,
                attempts: job.attempts,
                cells: job.cells,
            });
            job.attempts += 1;
            job.state = JobState::Active;
            job.handle = Some(handle);
            ledger.record(JobStatus {
                job: id,
                tenant: job.tenant,
                state: JobState::Active,
                attempts: job.attempts,
                cells: job.cells,
            });
            shared.jobs_cv.notify_all();
        }
        Err(e) => {
            let attempts = job.attempts;
            let reason = if tenant_alive {
                format!("{error}; retry submission failed: {e}")
            } else {
                format!("{error}; tenant closed before retry")
            };
            finish(shared, st, id, JobState::Failed { attempts, error: reason });
        }
    }
}
