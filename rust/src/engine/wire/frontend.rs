//! The TCP front door: accepts wire tenants and multiplexes their jobs
//! onto an [`EngineServer`] so network clients and in-process
//! [`ClientSession`]s share one worker pool and one fairness discipline.
//!
//! Threading model (all std):
//!
//! - one **accept** thread; one **connection** thread per client socket
//!   (blocking reads with a short timeout so shutdown is prompt);
//! - one **reaper** thread that watches outstanding [`JobHandle`]s,
//!   records terminal transitions in the [`JobLedger`], runs the
//!   retry-with-max-attempts policy, and releases per-tenant quota.
//!
//! All mutable front-door state lives under ONE mutex (`Shared::state`);
//! the lock order is front-state → engine-state (via `ClientSession`
//! calls) → job-done, which is acyclic against the engine scheduler's own
//! engine-state → job-done order, so the combined system cannot deadlock.
//!
//! Sessions survive disconnects: a socket dying mid-job abandons nothing.
//! The tenant's jobs keep draining, and any connection may later poll or
//! fetch them by job id — that, plus journal replay in [`JobLedger`], is
//! what the kill-and-reconnect fault tests exercise. With checkpointing
//! on ([`WireConfig::checkpoint_every`]), jobs even survive process
//! death: `bind` replays the journal, finds each mid-flight job's
//! [`Checkpoint`] sidecar, and *resumes* it from the last grid barrier —
//! bit-identical to an uninterrupted run (DESIGN §3.4).
//!
//! With [`WireConfig::cluster`] set the front door is also the cluster
//! router (DESIGN §3.3/§3.5): a submit whose total cell-update cost
//! crosses the configured threshold — or whose session requested
//! `shards > 1` — bypasses the DRR pool and runs on the sharded
//! [`ClusterCoordinator`], in checkpoint-sized segments, on a dedicated
//! runner thread the reaper watches exactly like a pool [`JobHandle`].
//! A `ShardLost` there is a retryable attempt like any worker fault:
//! the fleet is respawned, fast-forwarded from the last checkpoint
//! sidecar when one exists.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cluster::{ClusterCoordinator, ShardMap, WorkerLauncher};
use crate::coordinator::{ExecReport, Plan};
use crate::model::PerfModel;
use crate::stencil::{Grid, StencilProgram, StencilRegistry};
use crate::util::json::Json;

use super::super::chaos::{ChaosCtx, ChaosPlan, FaultKind};
use super::super::server::{CheckpointSink, QUEUE_WAIT_BUCKETS};
use super::super::{
    Backend, ClientSession, EngineError, EngineServer, JobHandle, JobOutput, Workload,
};
use super::checkpoint::Checkpoint;
use super::protocol::{
    encode_frame, ErrorKind, GridPayload, PlanSpec, Request, Response, WireError,
    MAX_FRAME_BYTES,
};
use super::queue::{JobLedger, JobState, JobStatus};

/// How long a connection may dribble one frame's bytes before the read is
/// declared torn. Generous: a 64 MiB frame at 20 MB/s needs ~3.3 s.
const FRAME_DEADLINE: Duration = Duration::from_secs(30);

/// Poll interval for the first byte of a frame (bounds shutdown latency).
const IDLE_POLL: Duration = Duration::from_millis(100);

/// Memory-throughput roof handed to the routing [`PerfModel`]. High
/// enough that [`ClusterConfig::node_mcells`] — a *measured* rate — is
/// what actually bounds the per-node term for every built-in stencil.
const ROUTE_MODEL_GBPS: f64 = 20.0;

/// Cluster routing policy (DESIGN §3.3). When [`WireConfig::cluster`]
/// carries one of these, the front door routes big jobs through the
/// sharded [`ClusterCoordinator`] instead of the local DRR pool.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Route to the cluster once `grid cells × iterations` reaches this
    /// many cell updates *and* the perf model favours ≥ 2 shards. An
    /// explicit per-session `shards` request bypasses the threshold
    /// (`Some(1)` pins the session to the pool).
    pub route_threshold_cells: u64,
    /// Upper bound on shards per job; the partition's own feasibility
    /// (halo and tile fit, [`ShardMap::shardable`]) clamps further.
    pub max_shards: usize,
    /// Interconnect rate fed to [`PerfModel::cluster_mcells`] when
    /// scoring candidate shard counts.
    pub link_gbps: f64,
    /// Measured (or assumed) single-node rate in Mcell/s for the model.
    pub node_mcells: f64,
    /// How shard workers are hosted: real processes in production,
    /// threads for benches and tests.
    pub launcher: WorkerLauncher,
}

impl Default for ClusterConfig {
    fn default() -> ClusterConfig {
        ClusterConfig {
            route_threshold_cells: 8 << 20,
            max_shards: 4,
            link_gbps: 1.0,
            node_mcells: 2000.0,
            launcher: WorkerLauncher::Threads,
        }
    }
}

/// Front-door policy knobs. Defaults are deliberately modest — quotas are
/// the backpressure mechanism, so they should trip in tests long before
/// memory does.
#[derive(Debug, Clone)]
pub struct WireConfig {
    /// Per-tenant cap on jobs in flight (queued + active). Breaching it
    /// returns [`ErrorKind::QuotaJobs`] — backpressure, not failure.
    pub max_queued_jobs: usize,
    /// Per-tenant cap on total cells across jobs in flight
    /// ([`ErrorKind::QuotaCells`] beyond it).
    pub max_queued_cells: u64,
    /// Attempts (started) before a worker-side failure becomes terminal
    /// `Failed{attempts}`.
    pub max_attempts: u32,
    /// Append-only JSONL journal; replayed on bind so job ids and
    /// terminal statuses survive restarts. `None` = in-memory only.
    pub journal: Option<PathBuf>,
    /// Snapshot every job's grid to a [`Checkpoint`] sidecar each time
    /// this many iterations complete (at the next chunk barrier).
    /// Requires a journal; 0 = off.
    pub checkpoint_every: usize,
    /// Compact the journal on bind once it exceeds this many bytes
    /// (rewrite as one latest-state record per job). 0 = never.
    pub journal_rotate_bytes: u64,
    /// Seeded deterministic fault injection ([`ChaosPlan`]), threaded
    /// through tile execution, journal IO, checkpoint writes and
    /// connection handling. `None` = no faults.
    pub chaos: Option<Arc<ChaosPlan>>,
    /// Cluster routing policy; `None` keeps every job on the local pool.
    pub cluster: Option<ClusterConfig>,
}

impl Default for WireConfig {
    fn default() -> WireConfig {
        WireConfig {
            max_queued_jobs: 8,
            max_queued_cells: 1 << 26,
            max_attempts: 3,
            journal: None,
            checkpoint_every: 0,
            journal_rotate_bytes: 1 << 20,
            chaos: None,
            cluster: None,
        }
    }
}

/// What we keep to resubmit a job on retry.
struct RetryInput {
    grid: Grid,
    power: Option<Grid>,
    iterations: Option<usize>,
    /// Iterations already baked into `grid` (non-zero for a job resumed
    /// from a checkpoint: the snapshot grid carries `base_iter` of the
    /// job's `total`).
    base_iter: usize,
    /// The job's total iteration count, checkpoint bookkeeping included.
    total: usize,
}

/// A cluster attempt in flight: its runner thread plus the abort flag
/// that [`ClusterCoordinator::abort`] polls between protocol steps.
struct ClusterTask {
    thread: JoinHandle<Result<JobOutput, EngineError>>,
    abort: Arc<AtomicBool>,
}

/// Where one attempt is executing: the local DRR pool, or a cluster
/// runner thread driving sharded workers. The reaper treats both
/// identically — poll `is_done`, then `wait` for the typed result.
enum Running {
    Pool(JobHandle),
    Cluster(ClusterTask),
}

impl Running {
    fn is_done(&self) -> bool {
        match self {
            Running::Pool(h) => h.is_done(),
            Running::Cluster(t) => t.thread.is_finished(),
        }
    }

    fn cancel(&self) {
        match self {
            Running::Pool(h) => h.cancel(),
            Running::Cluster(t) => t.abort.store(true, Ordering::SeqCst),
        }
    }

    fn wait(self) -> Result<JobOutput, EngineError> {
        match self {
            Running::Pool(h) => h.wait(),
            Running::Cluster(t) => t.thread.join().unwrap_or_else(|_| {
                Err(EngineError::Execution("cluster runner panicked".to_string()))
            }),
        }
    }
}

/// One wire job's front-door state. The ledger mirrors `state`; the
/// ledger is the durable record, this is the live machinery.
struct WireJob {
    tenant: u64,
    state: JobState,
    /// Attempts *started* (first submission counts as 1).
    attempts: u32,
    cells: u64,
    cancel_requested: bool,
    /// Absolute wall-clock deadline; retries get the remaining budget.
    deadline: Option<Instant>,
    /// `Some(shards)` when attempts run on the cluster path — retries
    /// respawn the fleet at the same width instead of resubmitting to
    /// the pool.
    route: Option<usize>,
    handle: Option<Running>,
    input: Option<RetryInput>,
    /// Held for exactly one fetch by a `wait` — then the state stays
    /// `Done` but later waits get a plain status.
    output: Option<(Grid, Json)>,
}

/// One wire tenant: an engine session plus quota and traffic accounting.
struct Tenant {
    client: ClientSession,
    /// The fully-resolved plan spec, embedded in checkpoints so a
    /// rebound frontend can rebuild this session without the original
    /// open request.
    spec: PlanSpec,
    /// Plan facts the cluster router needs per submit, captured once at
    /// open so routing never rebuilds the plan: `max_halo()`, `tile[0]`
    /// and the deepest fused-step chunk (the model's `par_time`).
    plan_halo: usize,
    plan_tile0: usize,
    plan_par_time: usize,
    /// Jobs this tenant ran on the cluster path, and shard-loss retries
    /// spent on them (surfaced through `stats`).
    cluster_jobs: u64,
    shard_retries: u64,
    outstanding_jobs: u64,
    outstanding_cells: u64,
    frames_in: u64,
    frames_out: u64,
    bytes_in: u64,
    bytes_out: u64,
}

struct FrontState {
    ledger: JobLedger,
    sessions: HashMap<u64, Tenant>,
    jobs: HashMap<u64, WireJob>,
    next_session: u64,
}

struct Shared {
    cfg: WireConfig,
    /// Taken (to `None`) at shutdown so the engine can be stopped by
    /// value; handlers only ever borrow it briefly to open sessions.
    engine: Mutex<Option<EngineServer>>,
    state: Mutex<FrontState>,
    /// Signals job transitions to server-side `wait`ers and the reaper.
    jobs_cv: Condvar,
    /// `Arc` so cluster runner threads can watch it without holding the
    /// whole `Shared` (they do hold it — this keeps the flag cloneable
    /// into [`ClusterCoordinator`] plumbing too).
    shutting: Arc<AtomicBool>,
    /// Shard-level health counters (wire `ping` surfaces them): shards
    /// currently running, halo cells exchanged under overlap, and
    /// shard-loss retries spent.
    shards_active: AtomicU64,
    halo_overlapped: AtomicU64,
    shard_retries: AtomicU64,
    conns: Mutex<Vec<JoinHandle<()>>>,
    /// Bind time, for the health check's uptime.
    started: Instant,
    /// Set by [`WireFrontend::kill`]: checkpoint sinks stop writing and
    /// terminal cleanup stops deleting sidecars, freezing the on-disk
    /// state at the "crash" instant. Shared with sink closures by `Arc`
    /// (not via `Arc<Shared>`, which would cycle through the engine).
    ckpt_frozen: Arc<AtomicBool>,
    /// Connection ids for the ConnDrop chaos key.
    conn_seq: AtomicU64,
}

/// The wire front door. Owns the [`EngineServer`] it fronts; dropping it
/// (or calling [`WireFrontend::shutdown`]) drains in-flight work, records
/// terminal ledger states, and joins every thread it spawned.
pub struct WireFrontend {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    reaper: Option<JoinHandle<()>>,
}

impl WireFrontend {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) in front of
    /// `server`. Replays the journal first when one is configured, so
    /// jobs interrupted by the previous run answer polls truthfully:
    /// each orphan with a valid [`Checkpoint`] sidecar is *resumed* from
    /// its last grid barrier (ledger records `Resumed{from_iter}`); the
    /// rest are healed to `Failed`. Oversized journals are compacted
    /// before serving.
    pub fn bind(
        addr: &str,
        server: EngineServer,
        cfg: WireConfig,
    ) -> std::io::Result<WireFrontend> {
        let ledger = match &cfg.journal {
            Some(path) => {
                let mut l = JobLedger::open_deferred(path)?;
                if let Some(ch) = &cfg.chaos {
                    l.set_chaos(Arc::clone(ch));
                }
                l
            }
            None => JobLedger::in_memory(),
        };
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let shared = Arc::new(Shared {
            cfg,
            engine: Mutex::new(Some(server)),
            state: Mutex::new(FrontState {
                ledger,
                sessions: HashMap::new(),
                jobs: HashMap::new(),
                next_session: 1,
            }),
            jobs_cv: Condvar::new(),
            shutting: Arc::new(AtomicBool::new(false)),
            shards_active: AtomicU64::new(0),
            halo_overlapped: AtomicU64::new(0),
            shard_retries: AtomicU64::new(0),
            conns: Mutex::new(Vec::new()),
            started: Instant::now(),
            ckpt_frozen: Arc::new(AtomicBool::new(false)),
            conn_seq: AtomicU64::new(0),
        });
        // Orphan triage + housekeeping, all before any thread serves a
        // request, so clients only ever observe the settled ledger.
        {
            let mut st = shared.state.lock().expect("front state poisoned");
            if let Some(journal) = shared.cfg.journal.clone() {
                for id in st.ledger.orphans() {
                    if resume_orphan(&shared, &mut st, &journal, id).is_err() {
                        st.ledger.heal(id);
                        let _ =
                            std::fs::remove_file(Checkpoint::path_for(&journal, id));
                    }
                }
            }
            // Session ids must not collide with tenants replayed (and
            // possibly re-created, above) from the journal.
            let max_tenant = st.ledger.jobs().map(|s| s.tenant).max().unwrap_or(0);
            st.next_session = st.next_session.max(max_tenant + 1);
            let rotate = shared.cfg.journal_rotate_bytes;
            if rotate > 0 && st.ledger.journal_bytes() > rotate {
                let _ = st.ledger.compact();
            }
        }
        let accept_shared = Arc::clone(&shared);
        let accept =
            std::thread::spawn(move || accept_loop(&accept_shared, &listener));
        let reaper_shared = Arc::clone(&shared);
        let reaper = std::thread::spawn(move || reaper_loop(&reaper_shared));
        Ok(WireFrontend {
            shared,
            addr: local,
            accept: Some(accept),
            reaper: Some(reaper),
        })
    }

    /// The bound address (resolves `:0` to the ephemeral port picked).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Open an **in-process** session on the same engine the wire tenants
    /// use: both populations share one worker pool and one DRR fairness
    /// discipline — the multiplexing claim, as an API.
    pub fn open_local(&self, plan: Plan) -> Result<ClientSession, EngineError> {
        let guard = self.shared.engine.lock().expect("engine slot poisoned");
        match guard.as_ref() {
            Some(server) => server.open(plan),
            None => Err(EngineError::Shutdown),
        }
    }

    /// Job ids healed to `Failed` during journal replay (were mid-flight
    /// when the previous process died, with no usable checkpoint).
    pub fn healed_jobs(&self) -> Vec<u64> {
        self.shared.state.lock().expect("front state poisoned").ledger.healed.clone()
    }

    /// Jobs resumed from a checkpoint during journal replay:
    /// `(job, from_iter)` — the job restarted with `from_iter` of its
    /// iterations already done.
    pub fn resumed_jobs(&self) -> Vec<(u64, usize)> {
        self.shared.state.lock().expect("front state poisoned").ledger.resumed.clone()
    }

    /// Crash simulation (tests): freeze the journal and every checkpoint
    /// sidecar at this instant — no further journal appends, checkpoint
    /// writes or sidecar deletions — then tear down threads. The on-disk
    /// state is exactly what a SIGKILL at this point would have left, so
    /// a subsequent [`WireFrontend::bind`] exercises the real
    /// resume-or-heal path.
    pub fn kill(&mut self) {
        self.shared.ckpt_frozen.store(true, Ordering::SeqCst);
        self.shared.state.lock().expect("front state poisoned").ledger.freeze();
        self.shutdown();
    }

    /// Latest ledger status of a job (ops/test introspection; the wire
    /// `poll` request is the protocol-level equivalent).
    pub fn job_status(&self, job: u64) -> Option<JobStatus> {
        self.shared
            .state
            .lock()
            .expect("front state poisoned")
            .ledger
            .status(job)
            .cloned()
    }

    /// Graceful shutdown: stop accepting, join connections, stop the
    /// engine (which completes every outstanding handle), let the reaper
    /// drain those completions into terminal ledger states, then join it.
    /// Idempotent; runs on drop.
    pub fn shutdown(&mut self) {
        if self.shared.shutting.swap(true, Ordering::SeqCst) {
            // Another call already ran the sequence; just reap handles.
            if let Some(h) = self.accept.take() {
                let _ = h.join();
            }
            if let Some(h) = self.reaper.take() {
                let _ = h.join();
            }
            return;
        }
        // Cluster attempts poll their abort flag between protocol steps;
        // raise it on every in-flight one so the fleets are reaped and
        // the runners return promptly. With `shutting` already set the
        // runner reports Shutdown, and resolve() turns that into
        // `Failed{"interrupted..."}` — or Cancelled if the tenant had
        // asked first — exactly like a drained pool job.
        {
            let st = self.shared.state.lock().expect("front state poisoned");
            for j in st.jobs.values() {
                if let Some(Running::Cluster(t)) = &j.handle {
                    t.abort.store(true, Ordering::SeqCst);
                }
            }
        }
        // Wake the blocking accept() with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let conns: Vec<JoinHandle<()>> = {
            let mut guard = self.shared.conns.lock().expect("conns poisoned");
            guard.drain(..).collect()
        };
        for c in conns {
            let _ = c.join();
        }
        if let Some(mut server) =
            self.shared.engine.lock().expect("engine slot poisoned").take()
        {
            server.shutdown();
        }
        self.shared.jobs_cv.notify_all();
        if let Some(h) = self.reaper.take() {
            let _ = h.join();
        }
    }
}

impl Drop for WireFrontend {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ----------------------------------------------------------- accept loop

fn accept_loop(shared: &Arc<Shared>, listener: &TcpListener) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if shared.shutting.load(Ordering::SeqCst) {
                    return;
                }
                let conn_shared = Arc::clone(shared);
                let handle =
                    std::thread::spawn(move || connection_loop(&conn_shared, stream));
                let mut conns = shared.conns.lock().expect("conns poisoned");
                conns.retain(|c| !c.is_finished());
                conns.push(handle);
            }
            Err(_) => {
                if shared.shutting.load(Ordering::SeqCst) {
                    return;
                }
                // Transient accept failure (fd pressure); back off briefly.
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
}

// ------------------------------------------------------ connection loop

/// Read one frame, shutdown-aware. The FIRST byte is polled with a short
/// timeout (checking the shutting flag between polls); once a frame has
/// started, the rest of the header and body are read under a deadline —
/// so a slow-but-live client streaming a megabyte grid is never cut off,
/// while a wedged peer cannot pin the thread past [`FRAME_DEADLINE`].
/// Returns `Ok(None)` when the server is shutting down.
fn read_frame_patient(
    stream: &mut TcpStream,
    shutting: &AtomicBool,
) -> Result<Option<Json>, WireError> {
    let mut first = [0u8; 1];
    loop {
        if shutting.load(Ordering::SeqCst) {
            return Ok(None);
        }
        match stream.read(&mut first) {
            Ok(0) => return Err(WireError::Closed),
            Ok(_) => break,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                continue;
            }
            Err(e) => return Err(e.into()),
        }
    }
    let deadline = Instant::now() + FRAME_DEADLINE;
    let mut header = [0u8; 4];
    header[0] = first[0];
    read_deadline(stream, &mut header[1..], deadline, 4, shutting)?;
    let len = u32::from_be_bytes(header) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(WireError::Oversized { len, max: MAX_FRAME_BYTES });
    }
    let mut body = vec![0u8; len];
    read_deadline(stream, &mut body, deadline, len, shutting)?;
    let text = String::from_utf8(body)
        .map_err(|e| WireError::BadJson(format!("invalid utf-8: {e}")))?;
    Json::parse(&text)
        .map(Some)
        .map_err(|e| WireError::BadJson(e.to_string()))
}

/// Deadline-bounded `read_exact`. Also aborts mid-frame on shutdown —
/// the server is going down and the submit would be rejected anyway, so
/// bounded shutdown latency wins over finishing the transfer.
fn read_deadline(
    stream: &mut TcpStream,
    buf: &mut [u8],
    deadline: Instant,
    want: usize,
    shutting: &AtomicBool,
) -> Result<(), WireError> {
    let mut got = 0;
    while got < buf.len() {
        if Instant::now() >= deadline || shutting.load(Ordering::SeqCst) {
            return Err(WireError::Torn { got, want });
        }
        match stream.read(&mut buf[got..]) {
            Ok(0) => return Err(WireError::Torn { got, want }),
            Ok(n) => got += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                continue;
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

fn send_response(stream: &mut TcpStream, resp: &Response) -> bool {
    let frame = encode_frame(&resp.to_json());
    stream.write_all(&frame).and_then(|()| stream.flush()).is_ok()
}

fn connection_loop(shared: &Arc<Shared>, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(IDLE_POLL));
    let conn = shared.conn_seq.fetch_add(1, Ordering::SeqCst);
    let mut frame_i: u64 = 0;
    loop {
        match read_frame_patient(&mut stream, &shared.shutting) {
            Ok(None) | Err(WireError::Closed) => return,
            Ok(Some(msg)) => {
                frame_i += 1;
                // Body length approximated by re-serialization (byte-
                // identical for frames our own client sends), +4 header.
                let in_bytes = msg.to_string().len() as u64 + 4;
                let (resp, tenant) = handle_frame(shared, &msg);
                let frame = encode_frame(&resp.to_json());
                attribute_traffic(shared, tenant, in_bytes, frame.len() as u64);
                if stream.write_all(&frame).and_then(|()| stream.flush()).is_err() {
                    return;
                }
                // Chaos: sever the connection after the response. The
                // session and its jobs survive — exactly the disconnect
                // resilience the reconnect tests assert.
                if let Some(ch) = &shared.cfg.chaos {
                    if ch.should(FaultKind::ConnDrop, conn, 0, frame_i) {
                        return;
                    }
                }
            }
            Err(WireError::BadJson(m)) => {
                // Frame length was honored, so the stream is still in
                // sync — report the garbage and keep serving.
                let ok = send_response(
                    &mut stream,
                    &Response::Error { kind: ErrorKind::BadFrame, message: m },
                );
                if !ok {
                    return;
                }
            }
            Err(e @ WireError::Oversized { .. }) => {
                // Body unread → framing is lost; answer, then hang up.
                let _ = send_response(
                    &mut stream,
                    &Response::Error { kind: ErrorKind::BadFrame, message: e.to_string() },
                );
                return;
            }
            // Torn frame or transport error: the byte stream can no
            // longer be trusted. Drop the connection; the session and
            // its jobs survive for the next connection to pick up.
            Err(_) => return,
        }
    }
}

fn attribute_traffic(shared: &Arc<Shared>, tenant: Option<u64>, inb: u64, outb: u64) {
    let Some(id) = tenant else { return };
    let mut st = shared.state.lock().expect("front state poisoned");
    if let Some(t) = st.sessions.get_mut(&id) {
        t.frames_in += 1;
        t.frames_out += 1;
        t.bytes_in += inb;
        t.bytes_out += outb;
    }
}

// -------------------------------------------------------- frame handling

/// Decode and dispatch one request. Returns the response plus the tenant
/// the traffic should be attributed to (if the request named one).
fn handle_frame(shared: &Arc<Shared>, msg: &Json) -> (Response, Option<u64>) {
    let req = match Request::from_json(msg) {
        Ok(r) => r,
        Err(e) => {
            return (
                Response::Error { kind: ErrorKind::BadRequest, message: e.to_string() },
                None,
            )
        }
    };
    match req {
        Request::Ping => (handle_ping(shared), None),
        Request::Open { plan, programs } => handle_open(shared, &plan, &programs),
        Request::Submit { session, grid, power, iterations, deadline_ms } => (
            handle_submit(shared, session, &grid, power.as_ref(), iterations, deadline_ms),
            Some(session),
        ),
        Request::Poll { job } => {
            let st = shared.state.lock().expect("front state poisoned");
            let tenant = st.ledger.status(job).map(|s| s.tenant);
            (status_response(&st, job), tenant)
        }
        Request::Wait { job, timeout_ms } => handle_wait(shared, job, timeout_ms),
        Request::Cancel { job } => handle_cancel(shared, job),
        Request::Stats { session } => (handle_stats(shared, session), Some(session)),
        Request::Close { session } => {
            let mut st = shared.state.lock().expect("front state poisoned");
            match st.sessions.remove(&session) {
                // Dropping the Tenant drops its ClientSession: the engine
                // marks the slot closed and reaps it once queued jobs
                // drain. Outstanding wire jobs stay poll-able by id.
                Some(_) => (Response::Closed { session }, None),
                None => (
                    Response::Error {
                        kind: ErrorKind::UnknownSession,
                        message: format!("no session {session}"),
                    },
                    None,
                ),
            }
        }
    }
}

/// Liveness probe, now a health check: uptime, pool size, live job
/// counts and whether chaos injection is armed. Lock order: front-state
/// is taken and released before the engine slot — never nested.
fn handle_ping(shared: &Arc<Shared>) -> Response {
    let (jobs_queued, jobs_active) = {
        let st = shared.state.lock().expect("front state poisoned");
        let mut queued = 0u64;
        let mut active = 0u64;
        for j in st.jobs.values() {
            match j.state {
                JobState::Queued => queued += 1,
                JobState::Active | JobState::Resumed { .. } => active += 1,
                _ => {}
            }
        }
        (queued, active)
    };
    let workers = {
        let guard = shared.engine.lock().expect("engine slot poisoned");
        guard.as_ref().map(EngineServer::workers).unwrap_or(0)
    };
    Response::Pong {
        uptime_ms: shared.started.elapsed().as_millis() as u64,
        workers: workers as u64,
        jobs_queued,
        jobs_active,
        chaos: shared.cfg.chaos.is_some(),
        shards_active: shared.shards_active.load(Ordering::SeqCst),
        halo_overlapped: shared.halo_overlapped.load(Ordering::SeqCst),
        shard_retries: shared.shard_retries.load(Ordering::SeqCst),
    }
}

fn handle_open(
    shared: &Arc<Shared>,
    spec: &PlanSpec,
    programs: &[Json],
) -> (Response, Option<u64>) {
    if shared.shutting.load(Ordering::SeqCst) {
        return (shutting_error(), None);
    }
    // Inline programs first (registration is idempotent-by-content), so
    // the plan spec can reference stencils defined in the same request.
    for p in programs {
        let program = match StencilProgram::from_json(p) {
            Ok(prog) => prog,
            Err(e) => {
                return (
                    Response::Error {
                        kind: ErrorKind::Plan,
                        message: format!("bad inline stencil program: {e:#}"),
                    },
                    None,
                )
            }
        };
        if let Err(e) = StencilRegistry::register(program) {
            return (
                Response::Error {
                    kind: ErrorKind::Plan,
                    message: format!("stencil registration failed: {e:#}"),
                },
                None,
            );
        }
    }
    let plan = match spec.build() {
        Ok(p) => p,
        Err(e) => {
            // Prefer the auditor's structured diagnostics over the
            // builder's single message: a spec the builder refuses
            // (halo-swallowed tile, unschedulable iterations, ...) comes
            // back as a typed report the client can render field by field.
            if let Some(report) = audit_spec(spec) {
                return (
                    Response::Rejected {
                        message: EngineError::Rejected(report.clone()).to_string(),
                        diagnostics: report.to_json(),
                    },
                    None,
                );
            }
            return (
                Response::Error { kind: ErrorKind::Plan, message: e.to_string() },
                None,
            );
        }
    };
    // The fully-resolved spec (defaults filled in by the builder) is what
    // checkpoints embed — it must rebuild this exact plan after restart.
    // The shard request is routing policy, not a plan parameter, so the
    // builder drops it; carry it over explicitly.
    let mut full_spec = PlanSpec::from_plan(&plan);
    full_spec.shards = spec.shards;
    let plan_halo = plan.max_halo();
    let plan_tile0 = plan.tile[0];
    let plan_par_time = plan.chunks.iter().copied().max().unwrap_or(1);
    // Engine session queue depth exceeds the wire quota, so a quota-
    // admitted submit can never block on engine backpressure while the
    // front-state lock is held (quota is checked under that lock first).
    let depth = shared.cfg.max_queued_jobs.max(1) + 1;
    let client = {
        let guard = shared.engine.lock().expect("engine slot poisoned");
        match guard.as_ref() {
            Some(server) => server.open_with_queue(plan, depth),
            None => Err(EngineError::Shutdown),
        }
    };
    let client = match client {
        Ok(c) => c,
        Err(e) => return (engine_error(&e), None),
    };
    let mut st = shared.state.lock().expect("front state poisoned");
    let session = st.next_session;
    st.next_session += 1;
    st.sessions.insert(
        session,
        Tenant {
            client,
            spec: full_spec,
            plan_halo,
            plan_tile0,
            plan_par_time,
            cluster_jobs: 0,
            shard_retries: 0,
            outstanding_jobs: 0,
            outstanding_cells: 0,
            frames_in: 0,
            frames_out: 0,
            bytes_in: 0,
            bytes_out: 0,
        },
    );
    (Response::Opened { session }, Some(session))
}

fn handle_submit(
    shared: &Arc<Shared>,
    session: u64,
    grid: &GridPayload,
    power: Option<&GridPayload>,
    iterations: Option<usize>,
    deadline_ms: Option<u64>,
) -> Response {
    if shared.shutting.load(Ordering::SeqCst) {
        return shutting_error();
    }
    // Decode payloads before taking any lock — base64 of a big grid is
    // real CPU work and needs no shared state.
    let grid = match grid.to_grid() {
        Ok(g) => g,
        Err(e) => {
            return Response::Error { kind: ErrorKind::BadRequest, message: e.to_string() }
        }
    };
    let power = match power.map(GridPayload::to_grid).transpose() {
        Ok(p) => p,
        Err(e) => {
            return Response::Error { kind: ErrorKind::BadRequest, message: e.to_string() }
        }
    };
    let cells = grid.len() as u64;

    let mut st = shared.state.lock().expect("front state poisoned");
    let Some(tenant) = st.sessions.get(&session) else {
        return Response::Error {
            kind: ErrorKind::UnknownSession,
            message: format!("no session {session}"),
        };
    };
    // Quotas are the typed-backpressure surface: the client is told to
    // drain, nothing is charged, and other tenants are untouched.
    if tenant.outstanding_jobs >= shared.cfg.max_queued_jobs as u64 {
        return Response::Error {
            kind: ErrorKind::QuotaJobs,
            message: format!(
                "tenant has {} jobs in flight (quota {})",
                tenant.outstanding_jobs, shared.cfg.max_queued_jobs
            ),
        };
    }
    if tenant.outstanding_cells + cells > shared.cfg.max_queued_cells {
        return Response::Error {
            kind: ErrorKind::QuotaCells,
            message: format!(
                "tenant has {} cells in flight; {} more exceeds the {}-cell quota",
                tenant.outstanding_cells, cells, shared.cfg.max_queued_cells
            ),
        };
    }
    // The job's total iteration count: the per-submit override, else the
    // tenant plan's default. Checkpoints track progress against this.
    let total = iterations.unwrap_or(tenant.spec.iterations);
    let spec = tenant.spec.clone();
    let route = route_shards(
        shared.cfg.cluster.as_ref(),
        &spec,
        tenant.plan_halo,
        tenant.plan_tile0,
        tenant.plan_par_time,
        cells,
        total,
    );
    let deadline = deadline_ms.map(Duration::from_millis);
    let abs_deadline = deadline.map(|d| Instant::now() + d);
    // Allocate the id before the engine sees the job so the checkpoint
    // sink can be keyed on it. A submit the engine then rejects burns the
    // id — harmless, nothing was recorded under it.
    let job = st.ledger.allocate();
    let running = if let Some(shards) = route {
        // Cluster path. The coordinator re-validates shape/power on its
        // own run path, but those faults are *submission* errors, not
        // retryable attempts — reject them here like the pool would.
        if grid.dims() != spec.grid_dims {
            return engine_error(&EngineError::GridShape {
                expected: spec.grid_dims.clone(),
                got: grid.dims(),
            });
        }
        let has_power =
            StencilRegistry::lookup(&spec.stencil).map(|id| id.def().has_power).unwrap_or(false);
        if power.is_some() != has_power {
            return engine_error(&EngineError::PowerMismatch {
                expected: has_power,
                got: power.is_some(),
            });
        }
        // Charge the tenant's DRR slot for the bypassed work so pool
        // fairness accounting stays honest against all-cluster tenants.
        let t = st.sessions.get_mut(&session).expect("tenant checked above");
        t.client.record_bypass(cells.saturating_mul(total as u64));
        t.cluster_jobs += 1;
        spawn_cluster(
            shared,
            ClusterAttempt {
                spec: spec.clone(),
                shards,
                job,
                tenant: session,
                attempt: 1,
                grid: grid.clone(),
                power: power.clone(),
                total,
                base: 0,
                deadline: abs_deadline,
            },
        )
    } else {
        let mut workload = Workload::new(grid.clone());
        if let Some(p) = &power {
            workload = workload.power(p.clone());
        }
        if let Some(i) = iterations {
            workload = workload.iterations(i);
        }
        if let Some(d) = deadline {
            workload = workload.deadline(d);
        }
        let workload =
            arm_workload(shared, workload, job, session, 1, &spec, power.as_ref(), total, 0);
        // Never blocks: quota admitted < engine queue depth (see
        // handle_open).
        let tenant = st.sessions.get(&session).expect("tenant checked above");
        match tenant.client.submit(workload) {
            Ok(h) => Running::Pool(h),
            // Validation failed — nothing was accepted, charge nothing.
            Err(e) => return engine_error(&e),
        }
    };
    st.ledger.record(JobStatus {
        job,
        tenant: session,
        state: JobState::Queued,
        attempts: 0,
        cells,
    });
    st.ledger.record(JobStatus {
        job,
        tenant: session,
        state: JobState::Active,
        attempts: 1,
        cells,
    });
    st.jobs.insert(
        job,
        WireJob {
            tenant: session,
            state: JobState::Active,
            attempts: 1,
            cells,
            cancel_requested: false,
            deadline: abs_deadline,
            route,
            handle: Some(running),
            input: Some(RetryInput { grid, power, iterations, base_iter: 0, total }),
            output: None,
        },
    );
    let t = st.sessions.get_mut(&session).expect("tenant checked above");
    t.outstanding_jobs += 1;
    t.outstanding_cells += cells;
    shared.jobs_cv.notify_all();
    Response::Accepted { job }
}

/// Status snapshot from the ledger — answers for live jobs, finished
/// jobs, and jobs replayed from a previous process alike.
fn status_response(st: &FrontState, job: u64) -> Response {
    match st.ledger.status(job) {
        Some(s) => Response::Status { job, state: s.state.clone(), attempts: s.attempts },
        None => Response::Error {
            kind: ErrorKind::UnknownJob,
            message: format!("no job {job}"),
        },
    }
}

fn handle_wait(shared: &Arc<Shared>, job: u64, timeout_ms: u64) -> (Response, Option<u64>) {
    let deadline = Instant::now() + Duration::from_millis(timeout_ms);
    let mut st = shared.state.lock().expect("front state poisoned");
    let tenant = st.ledger.status(job).map(|s| s.tenant);
    loop {
        let Some(status) = st.ledger.status(job) else {
            return (
                Response::Error {
                    kind: ErrorKind::UnknownJob,
                    message: format!("no job {job}"),
                },
                None,
            );
        };
        if status.state.is_terminal() {
            let attempts = status.attempts;
            if status.state == JobState::Done {
                // The result is fetched-once: the first wait carries the
                // grid home and frees the buffer; later waits (and any
                // poll) see a plain Done status.
                if let Some((grid, report)) =
                    st.jobs.get_mut(&job).and_then(|j| j.output.take())
                {
                    return (
                        Response::Result {
                            job,
                            grid: GridPayload::from_grid(&grid),
                            attempts,
                            report,
                        },
                        tenant,
                    );
                }
            }
            return (status_response(&st, job), tenant);
        }
        let now = Instant::now();
        if now >= deadline || shared.shutting.load(Ordering::SeqCst) {
            return (status_response(&st, job), tenant);
        }
        // Short slices keep shutdown latency bounded even if a notify
        // is lost to a race.
        let slice = (deadline - now).min(Duration::from_millis(50));
        st = shared
            .jobs_cv
            .wait_timeout(st, slice)
            .expect("front state poisoned")
            .0;
    }
}

fn handle_cancel(shared: &Arc<Shared>, job: u64) -> (Response, Option<u64>) {
    let mut st = shared.state.lock().expect("front state poisoned");
    let tenant = st.ledger.status(job).map(|s| s.tenant);
    if tenant.is_none() {
        return (
            Response::Error { kind: ErrorKind::UnknownJob, message: format!("no job {job}") },
            None,
        );
    }
    if let Some(j) = st.jobs.get_mut(&job) {
        if !j.state.is_terminal() {
            j.cancel_requested = true;
            if let Some(h) = &j.handle {
                h.cancel();
            }
            shared.jobs_cv.notify_all();
        }
    }
    // Idempotent ack: current status (the reaper records Cancelled once
    // the engine confirms; a completion that wins the race stands).
    (status_response(&st, job), tenant)
}

fn handle_stats(shared: &Arc<Shared>, session: u64) -> Response {
    let st = shared.state.lock().expect("front state poisoned");
    let Some(t) = st.sessions.get(&session) else {
        return Response::Error {
            kind: ErrorKind::UnknownSession,
            message: format!("no session {session}"),
        };
    };
    let mut es = t.client.stats();
    // Cluster-side counters live on the frontend, not the engine; fold
    // them into the same stats surface the client already reads.
    es.cluster_jobs = t.cluster_jobs;
    es.cluster_shard_retries = t.shard_retries;
    let hist: Vec<Json> =
        (0..QUEUE_WAIT_BUCKETS).map(|i| Json::from(es.queue_wait_hist[i] as usize)).collect();
    let engine = Json::obj(vec![
        ("jobs_submitted", Json::from(es.jobs_submitted as usize)),
        ("jobs_completed", Json::from(es.jobs_completed as usize)),
        ("jobs_cancelled", Json::from(es.jobs_cancelled as usize)),
        ("jobs_failed", Json::from(es.jobs_failed as usize)),
        ("tiles_executed", Json::from(es.tiles_executed as usize)),
        ("nonfinite_trips", Json::from(es.nonfinite_trips as usize)),
        ("cell_updates", Json::from(es.cell_updates as usize)),
        ("max_queue_wait_us", Json::from(es.max_queue_wait.as_micros() as usize)),
        ("sched_served", Json::from(es.sched_served as usize)),
        ("sched_rounds", Json::from(es.sched_rounds as usize)),
        ("sched_bypassed", Json::from(es.sched_bypassed as usize)),
        ("cluster_jobs", Json::from(es.cluster_jobs as usize)),
        ("cluster_shard_retries", Json::from(es.cluster_shard_retries as usize)),
        // Bucket i counts dispatches whose submit→dispatch wait fell in
        // [2^i, 2^(i+1)) microseconds; the last bucket absorbs the tail.
        ("queue_wait_hist_us_pow2", Json::Arr(hist)),
    ]);
    let wire = Json::obj(vec![
        ("frames_in", Json::from(t.frames_in as usize)),
        ("frames_out", Json::from(t.frames_out as usize)),
        ("bytes_in", Json::from(t.bytes_in as usize)),
        ("bytes_out", Json::from(t.bytes_out as usize)),
        ("outstanding_jobs", Json::from(t.outstanding_jobs as usize)),
        ("outstanding_cells", Json::from(t.outstanding_cells as usize)),
    ]);
    Response::Stats {
        session,
        stats: Json::obj(vec![("engine", engine), ("wire", wire)]),
    }
}

fn shutting_error() -> Response {
    Response::Error {
        kind: ErrorKind::Shutdown,
        message: "server is shutting down".to_string(),
    }
}

/// Best-effort audit of a spec the builder refused: resolve the stencil
/// and backend if possible (otherwise there is nothing to audit), fill
/// the builder's defaults, and return the report iff it carries the
/// Error-level findings that explain the refusal.
fn audit_spec(spec: &PlanSpec) -> Option<crate::analysis::AuditReport> {
    let id = StencilRegistry::lookup(&spec.stencil)?;
    let backend = Backend::parse(&spec.backend).ok()?;
    let mut shape =
        crate::analysis::PlanShape::with_defaults(id, spec.grid_dims.clone(), spec.iterations);
    shape.backend = backend;
    if let Some(t) = &spec.tile {
        shape.tile = t.clone();
    }
    if let Some(c) = &spec.coeffs {
        shape.coeffs = c.clone();
    }
    if let Some(s) = &spec.step_sizes {
        shape.step_sizes = s.clone();
    }
    shape.workers = spec.workers;
    shape.guard_nonfinite = spec.guard_nonfinite.unwrap_or(false);
    let report = crate::analysis::audit_shape(&shape);
    report.has_errors().then_some(report)
}

fn engine_error(e: &EngineError) -> Response {
    let kind = match e {
        // A static-audit rejection carries its full report so the client
        // sees every diagnostic, not one flattened string.
        EngineError::Rejected(report) => {
            return Response::Rejected {
                message: e.to_string(),
                diagnostics: report.to_json(),
            };
        }
        EngineError::Shutdown => ErrorKind::Shutdown,
        EngineError::DeadlineExceeded => ErrorKind::DeadlineExceeded,
        _ => ErrorKind::Engine,
    };
    Response::Error { kind, message: e.to_string() }
}

// ------------------------------------------------------- cluster routing

/// Decide whether (and how wide) one job leaves the pool for the cluster
/// path. `None` = stay on the pool.
///
/// The widest *feasible* width comes first: every shard must keep at
/// least `halo` and `tile0` interior rows ([`ShardMap::shardable`] plus
/// the tile-fit guard — the same predicates the auditor's E010 check and
/// the coordinator's run-entry guard apply). An explicit `shards`
/// request is then clamped to that cap (`Some(1)` pins to the pool); an
/// unrequested job routes only when its cell-update cost crosses the
/// threshold *and* [`PerfModel::best_cluster_shards`] scores ≥ 2 shards
/// faster at the configured link rate.
fn route_shards(
    cluster: Option<&ClusterConfig>,
    spec: &PlanSpec,
    halo: usize,
    tile0: usize,
    par_time: usize,
    cells: u64,
    total: usize,
) -> Option<usize> {
    let cfg = cluster?;
    if spec.shards == Some(1) {
        return None;
    }
    let dim0 = *spec.grid_dims.first()?;
    let cap = (2..=cfg.max_shards.max(1).min(dim0)).rev().find(|&s| {
        let map = ShardMap::new(dim0, s);
        !map.has_empty_shard() && map.shardable(halo) && map.min_interior() >= tile0
    })?;
    if let Some(n) = spec.shards {
        return Some(n.min(cap)).filter(|&w| w >= 2);
    }
    if cells.saturating_mul(total as u64) < cfg.route_threshold_cells {
        return None;
    }
    let def = StencilRegistry::lookup(&spec.stencil)?.def();
    let best = PerfModel::new(ROUTE_MODEL_GBPS).best_cluster_shards(
        def,
        cfg.node_mcells,
        &spec.grid_dims,
        par_time,
        cfg.link_gbps,
        cap,
    );
    (best >= 2).then_some(best)
}

/// Everything one cluster attempt needs, owned outright so the runner
/// thread borrows nothing from front-door state.
struct ClusterAttempt {
    spec: PlanSpec,
    shards: usize,
    job: u64,
    tenant: u64,
    attempt: u32,
    grid: Grid,
    power: Option<Grid>,
    total: usize,
    /// Iterations already baked into `grid` (resume / sidecar retry).
    base: usize,
    deadline: Option<Instant>,
}

/// Start one cluster attempt on its own runner thread. The returned
/// [`Running::Cluster`] is reaped exactly like a pool handle.
fn spawn_cluster(shared: &Arc<Shared>, a: ClusterAttempt) -> Running {
    let abort = Arc::new(AtomicBool::new(false));
    let shared = Arc::clone(shared);
    let flag = Arc::clone(&abort);
    let thread = std::thread::spawn(move || run_cluster_attempt(&shared, &flag, a));
    Running::Cluster(ClusterTask { thread, abort })
}

/// One cluster attempt: run the job on the [`ClusterCoordinator`] in
/// checkpoint-sized segments, writing a [`Checkpoint`] sidecar at every
/// segment barrier. Segments end on accumulated greedy-schedule chunks,
/// so the stitched result is bit-identical to an uninterrupted run —
/// the same prefix property the resume path relies on (DESIGN §3.4).
fn run_cluster_attempt(
    shared: &Arc<Shared>,
    abort: &Arc<AtomicBool>,
    a: ClusterAttempt,
) -> Result<JobOutput, EngineError> {
    let shards = a.shards as u64;
    shared.shards_active.fetch_add(shards, Ordering::SeqCst);
    let r = cluster_segments(shared, abort, a);
    shared.shards_active.fetch_sub(shards, Ordering::SeqCst);
    r
}

fn cluster_segments(
    shared: &Arc<Shared>,
    abort: &Arc<AtomicBool>,
    a: ClusterAttempt,
) -> Result<JobOutput, EngineError> {
    let cluster =
        shared.cfg.cluster.clone().expect("cluster-routed job without cluster config");
    let started = Instant::now();
    let base_plan = a.spec.build()?;
    let checkpointing = shared.cfg.checkpoint_every > 0 && shared.cfg.journal.is_some();
    let mut grid = a.grid;
    let mut done = a.base;
    let mut passes = 0usize;
    while done < a.total {
        if shared.shutting.load(Ordering::SeqCst) {
            return Err(EngineError::Shutdown);
        }
        if abort.load(Ordering::SeqCst) {
            return Err(EngineError::Cancelled);
        }
        if a.deadline.is_some_and(|d| Instant::now() >= d) {
            return Err(EngineError::DeadlineExceeded);
        }
        let remaining = a.total - done;
        let segment = if checkpointing {
            segment_len(&base_plan, remaining, shared.cfg.checkpoint_every)
        } else {
            remaining
        };
        let mut seg_spec = a.spec.clone();
        seg_spec.iterations = segment;
        let seg_plan = seg_spec.build()?;
        passes += seg_plan.chunks.len();
        // Worker chaos is forwarded only on attempts the schedule
        // selects, so `kill=1@1` fells attempt 1's fleet and lets the
        // retry run clean — ShardLost-is-retryable, deterministically.
        let forward = shared.cfg.chaos.as_ref().filter(|c| {
            c.should(FaultKind::WorkerKill, a.job, a.attempt, done as u64)
        });
        let mut cc = ClusterCoordinator::new(seg_plan, a.shards)
            .launcher(cluster.launcher.clone())
            .abort(Arc::clone(abort));
        if let Some(c) = forward {
            cc = cc.chaos(c.to_string());
        }
        let rep = cc.run(&mut grid, a.power.as_ref()).map_err(|e| match e {
            // The abort flag is also how shutdown stops a fleet; report
            // what actually happened (resolve() still lets a tenant
            // cancel win over shutdown).
            EngineError::Cancelled if shared.shutting.load(Ordering::SeqCst) => {
                EngineError::Shutdown
            }
            other => other,
        })?;
        shared.halo_overlapped.fetch_add(rep.halo_cells_exchanged, Ordering::SeqCst);
        done += segment;
        if checkpointing && done < a.total {
            save_cluster_checkpoint(shared, &a, done, &grid);
        }
    }
    let cells: u64 = a.spec.grid_dims.iter().product::<usize>() as u64;
    Ok(JobOutput {
        grid,
        report: ExecReport {
            iterations: a.total - a.base,
            passes,
            tiles_executed: 0,
            cell_updates: cells * (a.total - a.base) as u64,
            redundant_updates: 0,
            elapsed: started.elapsed(),
            backend: "cluster",
            stages: None,
        },
    })
}

/// Iterations to run before the next checkpoint barrier: whole greedy
/// chunks accumulated to at least `every`, mirroring the engine's
/// chunk-barrier checkpoint cadence so segment seams land exactly where
/// an uninterrupted schedule would put a pass boundary.
fn segment_len(plan: &Plan, remaining: usize, every: usize) -> usize {
    let Ok(chunks) = plan.schedule_for(remaining) else { return remaining };
    let mut acc = 0usize;
    for steps in chunks {
        acc += steps;
        if acc >= every {
            break;
        }
    }
    acc.clamp(1, remaining)
}

/// Sidecar write at a cluster segment barrier — same format, path and
/// freeze/corrupt-chaos discipline as the engine-side [`CheckpointSink`].
fn save_cluster_checkpoint(shared: &Arc<Shared>, a: &ClusterAttempt, done: usize, grid: &Grid) {
    if shared.ckpt_frozen.load(Ordering::SeqCst) {
        return;
    }
    let Some(journal) = &shared.cfg.journal else { return };
    let ck = Checkpoint {
        job: a.job,
        tenant: a.tenant,
        attempt: a.attempt,
        total: a.total,
        done,
        plan: a.spec.clone(),
        grid: GridPayload::from_grid(grid),
        power: a.power.as_ref().map(GridPayload::from_grid),
    };
    let corrupt = shared.cfg.chaos.as_ref().is_some_and(|c| {
        c.should(FaultKind::CheckpointCorrupt, a.job, a.attempt, done as u64)
    });
    let _ = ck.save(&Checkpoint::path_for(journal, a.job), corrupt);
}

/// Fast-forward a cluster retry from the job's checkpoint sidecar, when
/// a valid one exists that is further along than the input already is.
/// An invalid or stale sidecar is simply ignored — the retry then
/// re-runs from the input it has (correct, just slower).
fn refresh_from_sidecar(cfg: &WireConfig, job: u64, tenant: u64, input: &mut RetryInput) -> bool {
    let Some(journal) = &cfg.journal else { return false };
    let Ok(ck) = Checkpoint::load(&Checkpoint::path_for(journal, job)) else { return false };
    if ck.job != job || ck.tenant != tenant || ck.total != input.total {
        return false;
    }
    if ck.done <= input.base_iter || ck.done >= ck.total {
        return false;
    }
    let Ok(grid) = ck.grid.to_grid() else { return false };
    let Ok(power) = ck.power.as_ref().map(GridPayload::to_grid).transpose() else {
        return false;
    };
    input.grid = grid;
    input.power = power;
    input.base_iter = ck.done;
    true
}

// ------------------------------------------------- crash safety plumbing

/// Attach the crash-safety machinery to one engine submission: the chaos
/// context (so tile faults key on the *wire* job id and attempt) and,
/// when checkpointing is on, a self-contained snapshot sink.
///
/// The sink runs on the engine scheduler thread, so it must not touch
/// `Shared::state` (lock order: front-state → engine-state; the scheduler
/// holds engine-state). Everything it needs is captured by value, plus
/// the frozen flag by `Arc`.
#[allow(clippy::too_many_arguments)]
fn arm_workload(
    shared: &Arc<Shared>,
    mut w: Workload,
    job: u64,
    tenant: u64,
    attempt: u32,
    spec: &PlanSpec,
    power: Option<&Grid>,
    total: usize,
    base: usize,
) -> Workload {
    if let Some(ch) = &shared.cfg.chaos {
        w = w.chaos(ChaosCtx { plan: Arc::clone(ch), job, attempt });
    }
    let every = shared.cfg.checkpoint_every;
    if every == 0 {
        return w;
    }
    let Some(journal) = shared.cfg.journal.clone() else { return w };
    let path = Checkpoint::path_for(&journal, job);
    let plan_spec = spec.clone();
    let power_payload = power.map(GridPayload::from_grid);
    let chaos = shared.cfg.chaos.clone();
    let frozen = Arc::clone(&shared.ckpt_frozen);
    let sink: CheckpointSink = Arc::new(move |iters_done: usize, grid: &Grid| {
        if frozen.load(Ordering::SeqCst) {
            return;
        }
        let done = base + iters_done;
        let ck = Checkpoint {
            job,
            tenant,
            attempt,
            total,
            done,
            plan: plan_spec.clone(),
            grid: GridPayload::from_grid(grid),
            power: power_payload.clone(),
        };
        let corrupt = chaos
            .as_ref()
            .is_some_and(|c| c.should(FaultKind::CheckpointCorrupt, job, attempt, done as u64));
        // Best-effort: a failed snapshot only costs resume granularity.
        let _ = ck.save(&path, corrupt);
    });
    w.checkpoint(every, sink)
}

/// Try to resume one journal orphan from its checkpoint sidecar. Any
/// `Err` sends the caller down the heal path — a torn/corrupt/stale
/// sidecar must degrade to the pre-checkpoint behavior, never resume
/// from poison. On success the job is live again: ledger shows
/// `Resumed{from_iter}`, the engine is running `total - done` iterations
/// from the snapshot grid, and the result is bit-identical to an
/// uninterrupted run (greedy-schedule suffix property, DESIGN §3.4).
fn resume_orphan(
    shared: &Arc<Shared>,
    st: &mut FrontState,
    journal: &Path,
    id: u64,
) -> Result<(), String> {
    let ck = Checkpoint::load(&Checkpoint::path_for(journal, id))?;
    if ck.job != id {
        return Err(format!("sidecar names job {}, expected {id}", ck.job));
    }
    if ck.done == 0 || ck.done >= ck.total {
        return Err(format!(
            "checkpoint at {}/{} iterations is not resumable",
            ck.done, ck.total
        ));
    }
    let prev =
        st.ledger.status(id).cloned().ok_or_else(|| "job not in ledger".to_string())?;
    if prev.tenant != ck.tenant {
        return Err(format!(
            "sidecar names tenant {}, journal says {}",
            ck.tenant, prev.tenant
        ));
    }
    let grid = ck.grid.to_grid().map_err(|e| e.to_string())?;
    let power =
        ck.power.as_ref().map(GridPayload::to_grid).transpose().map_err(|e| e.to_string())?;
    // Recreate the owning tenant session if the restart lost it. Inline
    // stencil programs die with the process registry, so a plan built on
    // one fails here and the job heals — the documented degradation.
    if !st.sessions.contains_key(&ck.tenant) {
        let plan = ck.plan.build().map_err(|e| e.to_string())?;
        let plan_halo = plan.max_halo();
        let plan_tile0 = plan.tile[0];
        let plan_par_time = plan.chunks.iter().copied().max().unwrap_or(1);
        let depth = shared.cfg.max_queued_jobs.max(1) + 1;
        let client = {
            let guard = shared.engine.lock().expect("engine slot poisoned");
            match guard.as_ref() {
                Some(server) => {
                    server.open_with_queue(plan, depth).map_err(|e| e.to_string())?
                }
                None => return Err("engine is shut down".to_string()),
            }
        };
        st.sessions.insert(
            ck.tenant,
            Tenant {
                client,
                spec: ck.plan.clone(),
                plan_halo,
                plan_tile0,
                plan_par_time,
                cluster_jobs: 0,
                shard_retries: 0,
                outstanding_jobs: 0,
                outstanding_cells: 0,
                frames_in: 0,
                frames_out: 0,
                bytes_in: 0,
                bytes_out: 0,
            },
        );
    }
    let attempts = prev.attempts + 1;
    let cells = grid.len() as u64;
    let remaining = ck.total - ck.done;
    // The resumed remainder routes by the same rule a fresh submit would
    // use, so a big job interrupted mid-cluster-run continues sharded.
    let tenant = st.sessions.get(&ck.tenant).expect("tenant ensured above");
    let route = route_shards(
        shared.cfg.cluster.as_ref(),
        &ck.plan,
        tenant.plan_halo,
        tenant.plan_tile0,
        tenant.plan_par_time,
        cells,
        remaining,
    );
    let handle = if let Some(shards) = route {
        let t = st.sessions.get_mut(&ck.tenant).expect("tenant ensured above");
        t.client.record_bypass(cells.saturating_mul(remaining as u64));
        t.cluster_jobs += 1;
        spawn_cluster(
            shared,
            ClusterAttempt {
                spec: ck.plan.clone(),
                shards,
                job: id,
                tenant: ck.tenant,
                attempt: attempts,
                grid: grid.clone(),
                power: power.clone(),
                total: ck.total,
                base: ck.done,
                deadline: None,
            },
        )
    } else {
        let mut w = Workload::new(grid.clone()).iterations(remaining);
        if let Some(p) = &power {
            w = w.power(p.clone());
        }
        w = arm_workload(
            shared,
            w,
            id,
            ck.tenant,
            attempts,
            &ck.plan,
            power.as_ref(),
            ck.total,
            ck.done,
        );
        let tenant = st.sessions.get(&ck.tenant).expect("tenant ensured above");
        Running::Pool(tenant.client.submit(w).map_err(|e| e.to_string())?)
    };
    st.ledger.mark_resumed(id, ck.done, attempts);
    st.jobs.insert(
        id,
        WireJob {
            tenant: ck.tenant,
            state: JobState::Resumed { from_iter: ck.done },
            attempts,
            cells,
            cancel_requested: false,
            deadline: None,
            route,
            handle: Some(handle),
            input: Some(RetryInput {
                grid,
                power,
                iterations: Some(remaining),
                base_iter: ck.done,
                total: ck.total,
            }),
            output: None,
        },
    );
    let t = st.sessions.get_mut(&ck.tenant).expect("tenant ensured above");
    t.outstanding_jobs += 1;
    t.outstanding_cells += cells;
    Ok(())
}

// ---------------------------------------------------------------- reaper

fn report_json(report: &ExecReport) -> Json {
    Json::obj(vec![
        ("iterations", Json::from(report.iterations)),
        ("passes", Json::from(report.passes)),
        ("tiles_executed", Json::from(report.tiles_executed as usize)),
        ("cell_updates", Json::from(report.cell_updates as usize)),
        ("redundant_updates", Json::from(report.redundant_updates as usize)),
        ("elapsed_ms", Json::from(report.elapsed.as_secs_f64() * 1e3)),
        ("backend", Json::from(report.backend)),
    ])
}

/// Watches outstanding handles; on completion applies the
/// retry/cancel/ledger policy. Single consumer of handle results, so
/// every transition is serialized through the front-state lock.
fn reaper_loop(shared: &Arc<Shared>) {
    loop {
        let mut st = shared.state.lock().expect("front state poisoned");
        let finished: Vec<u64> = st
            .jobs
            .iter()
            .filter(|(_, j)| j.handle.as_ref().is_some_and(Running::is_done))
            .map(|(&id, _)| id)
            .collect();
        for id in finished {
            let Some(handle) = st.jobs.get_mut(&id).and_then(|j| j.handle.take())
            else {
                continue;
            };
            // is_done() was true, so this returns without blocking.
            let result = handle.wait();
            resolve(shared, &mut st, id, result);
        }
        if !st.jobs.values().any(|j| j.handle.is_some())
            && shared.shutting.load(Ordering::SeqCst)
        {
            return;
        }
        let poll = if st.jobs.values().any(|j| j.handle.is_some()) {
            Duration::from_millis(2)
        } else {
            Duration::from_millis(200)
        };
        let _ = shared
            .jobs_cv
            .wait_timeout(st, poll)
            .expect("front state poisoned");
    }
}

/// What one completed attempt amounted to, snapshotted so no job borrow
/// survives into the state transitions below.
enum Outcome {
    Done(JobOutput),
    Cancelled,
    Shutdown,
    /// The deadline passed — terminal immediately, never retried (a
    /// retry could not finish any sooner than the attempt that expired).
    Deadline,
    Fail(String),
}

/// Apply one completed attempt's outcome. Precedence: a requested cancel
/// beats both failure and shutdown (the tenant asked for the job to stop;
/// how it stopped is incidental) — mirroring the engine-side
/// cancelled-then-shutdown fix in `server.rs`.
fn resolve(
    shared: &Arc<Shared>,
    st: &mut FrontState,
    id: u64,
    result: Result<JobOutput, EngineError>,
) {
    let cfg = &shared.cfg;
    let (attempts, cancel_requested) = {
        let job = st.jobs.get(&id).expect("resolving a known job");
        (job.attempts, job.cancel_requested)
    };
    let outcome = match result {
        Ok(out) => Outcome::Done(out),
        Err(EngineError::Cancelled) => Outcome::Cancelled,
        Err(EngineError::Shutdown) => Outcome::Shutdown,
        Err(EngineError::DeadlineExceeded) => Outcome::Deadline,
        Err(e) => Outcome::Fail(e.to_string()),
    };
    match outcome {
        Outcome::Done(out) => {
            let job = st.jobs.get_mut(&id).expect("resolving a known job");
            job.output = Some((out.grid, report_json(&out.report)));
            finish(shared, st, id, JobState::Done);
        }
        Outcome::Cancelled => finish(shared, st, id, JobState::Cancelled),
        Outcome::Shutdown => {
            let state = if cancel_requested {
                JobState::Cancelled
            } else {
                JobState::Failed {
                    attempts,
                    error: "server shutdown before the job finished".to_string(),
                }
            };
            finish(shared, st, id, state);
        }
        Outcome::Deadline => {
            let state = if cancel_requested {
                JobState::Cancelled
            } else {
                JobState::Failed {
                    attempts,
                    error: "deadline-exceeded: the job's deadline passed before it \
                            finished"
                        .to_string(),
                }
            };
            finish(shared, st, id, state);
        }
        Outcome::Fail(_) if cancel_requested => {
            finish(shared, st, id, JobState::Cancelled);
        }
        Outcome::Fail(error) if attempts < cfg.max_attempts => {
            retry(shared, st, id, &error);
        }
        Outcome::Fail(error) => {
            finish(shared, st, id, JobState::Failed { attempts, error });
        }
    }
}

/// Record a terminal state, release the tenant's quota, wake waiters.
/// The checkpoint sidecar is deleted — unless [`WireFrontend::kill`]
/// froze the on-disk state, in which case the crash snapshot stands.
fn finish(shared: &Arc<Shared>, st: &mut FrontState, id: u64, state: JobState) {
    let FrontState { ledger, sessions, jobs, .. } = st;
    let job = jobs.get_mut(&id).expect("finishing a known job");
    job.state = state.clone();
    job.input = None;
    if state != JobState::Done {
        job.output = None;
    }
    ledger.record(JobStatus {
        job: id,
        tenant: job.tenant,
        state,
        attempts: job.attempts,
        cells: job.cells,
    });
    // The tenant may have closed its session while the job drained.
    if let Some(t) = sessions.get_mut(&job.tenant) {
        t.outstanding_jobs = t.outstanding_jobs.saturating_sub(1);
        t.outstanding_cells = t.outstanding_cells.saturating_sub(job.cells);
    }
    if let Some(journal) = &shared.cfg.journal {
        if !shared.ckpt_frozen.load(Ordering::SeqCst) {
            let _ = std::fs::remove_file(Checkpoint::path_for(journal, id));
        }
    }
    shared.jobs_cv.notify_all();
}

/// Re-submit a failed attempt through the tenant's engine session — or,
/// for a cluster-routed job, respawn the shard fleet (fast-forwarded
/// from the last checkpoint sidecar when a valid one exists): a
/// `ShardLost` is a retryable ledger attempt, not a job failure. The
/// journal shows the full cycle either way: Queued(k) when the attempt
/// fails, Active(k+1) when the next one starts.
fn retry(shared: &Arc<Shared>, st: &mut FrontState, id: u64, error: &str) {
    let FrontState { ledger, sessions, jobs, .. } = st;
    let job = jobs.get_mut(&id).expect("retrying a known job");
    let (tenant_alive, resubmitted) = match sessions.get_mut(&job.tenant) {
        None => (false, Err(EngineError::Shutdown)),
        Some(t) if job.route.is_some() => {
            let shards = job.route.expect("checked in guard");
            let input = job.input.as_mut().expect("retryable job keeps its input");
            refresh_from_sidecar(&shared.cfg, id, job.tenant, input);
            shared.shard_retries.fetch_add(1, Ordering::SeqCst);
            t.shard_retries += 1;
            let running = spawn_cluster(
                shared,
                ClusterAttempt {
                    spec: t.spec.clone(),
                    shards,
                    job: id,
                    tenant: job.tenant,
                    attempt: job.attempts + 1,
                    grid: input.grid.clone(),
                    power: input.power.clone(),
                    total: input.total,
                    base: input.base_iter,
                    deadline: job.deadline,
                },
            );
            (true, Ok(running))
        }
        Some(t) => {
            let input = job.input.as_ref().expect("retryable job keeps its input");
            let mut w = Workload::new(input.grid.clone());
            if let Some(p) = &input.power {
                w = w.power(p.clone());
            }
            if let Some(i) = input.iterations {
                w = w.iterations(i);
            }
            if let Some(d) = job.deadline {
                // The remaining budget only; an already-expired deadline
                // becomes a zero budget and fails fast in the engine's
                // queued-deadline sweep.
                w = w.deadline(d.saturating_duration_since(Instant::now()));
            }
            let w = arm_workload(
                shared,
                w,
                id,
                job.tenant,
                job.attempts + 1,
                &t.spec,
                input.power.as_ref(),
                input.total,
                input.base_iter,
            );
            (true, t.client.submit(w).map(Running::Pool))
        }
    };
    match resubmitted {
        Ok(handle) => {
            ledger.record(JobStatus {
                job: id,
                tenant: job.tenant,
                state: JobState::Queued,
                attempts: job.attempts,
                cells: job.cells,
            });
            job.attempts += 1;
            job.state = JobState::Active;
            job.handle = Some(handle);
            ledger.record(JobStatus {
                job: id,
                tenant: job.tenant,
                state: JobState::Active,
                attempts: job.attempts,
                cells: job.cells,
            });
            shared.jobs_cv.notify_all();
        }
        Err(e) => {
            let attempts = job.attempts;
            let reason = if tenant_alive {
                format!("{error}; retry submission failed: {e}")
            } else {
                format!("{error}; tenant closed before retry")
            };
            finish(shared, st, id, JobState::Failed { attempts, error: reason });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(dims: &[usize], iterations: usize, shards: Option<usize>) -> PlanSpec {
        PlanSpec {
            stencil: "diffusion2d".to_string(),
            grid_dims: dims.to_vec(),
            iterations,
            backend: "scalar".to_string(),
            tile: None,
            coeffs: None,
            step_sizes: None,
            workers: None,
            guard_nonfinite: None,
            shards,
        }
    }

    #[test]
    fn explicit_shard_requests_clamp_to_the_feasible_cap() {
        // Threshold astronomically high: only the explicit request can
        // route. Cap over [256, 64] with a 32-row tile is 8 shards.
        let cfg = ClusterConfig {
            route_threshold_cells: u64::MAX,
            max_shards: 8,
            ..ClusterConfig::default()
        };
        let route = |sh| route_shards(Some(&cfg), &spec(&[256, 64], 8, sh), 2, 32, 2, 16384, 8);
        assert_eq!(route(Some(6)), Some(6));
        assert_eq!(route(Some(64)), Some(8), "request clamps to the feasible cap");
        assert_eq!(route(Some(1)), None, "shards=1 pins the session to the pool");
        // Unrequested jobs below the threshold stay on the pool.
        assert_eq!(route(None), None);
    }

    #[test]
    fn threshold_crossing_jobs_route_by_the_model() {
        // Same pinned scenario as the perf-model test: a fat 4096² grid
        // at 1 Gb/s favours the full 4 shards.
        let cfg = ClusterConfig {
            route_threshold_cells: 1,
            max_shards: 4,
            link_gbps: 1.0,
            node_mcells: 400.0,
            launcher: WorkerLauncher::Threads,
        };
        let sp = spec(&[4096, 4096], 8, None);
        let cells = 4096u64 * 4096;
        assert_eq!(route_shards(Some(&cfg), &sp, 4, 64, 4, cells, 8), Some(4));
        // No cluster config at all: never routes.
        assert_eq!(route_shards(None, &sp, 4, 64, 4, cells, 8), None);
    }

    #[test]
    fn infeasible_partitions_stay_on_the_pool() {
        // 64 rows with a 64-row tile: even 2 shards would leave slabs
        // thinner than the tile, so the request is refused — mirroring
        // the auditor's E010 predicate instead of failing at run time.
        let cfg = ClusterConfig { route_threshold_cells: 0, ..ClusterConfig::default() };
        let sp = spec(&[64, 64], 8, Some(2));
        assert_eq!(route_shards(Some(&cfg), &sp, 4, 64, 4, 4096, 8), None);
    }

    #[test]
    fn segments_end_on_greedy_chunk_boundaries() {
        let plan = spec(&[64, 64], 12, None).build().expect("plan builds");
        // Default step sizes [4,2,1] schedule 12 iterations as [4,4,4].
        assert_eq!(segment_len(&plan, 12, 6), 8, "4 < 6, so a second chunk accrues");
        assert_eq!(segment_len(&plan, 12, 1), 4, "never splits inside a chunk");
        assert_eq!(segment_len(&plan, 12, 100), 12, "caps at the remaining work");
        assert_eq!(segment_len(&plan, 2, 1), 2);
    }

    #[test]
    fn sidecar_refresh_fast_forwards_only_valid_snapshots() {
        let mut journal = std::env::temp_dir();
        journal.push(format!("fstencil-frontend-sidecar-{}", std::process::id()));
        let _ = std::fs::create_dir_all(&journal);
        journal.push("journal.jsonl");
        let cfg = WireConfig { journal: Some(journal.clone()), ..WireConfig::default() };
        let sp = spec(&[8, 8], 10, None);
        let mut snap = Grid::new2d(8, 8);
        snap.fill_random(3, -1.0, 1.0);
        let ck = Checkpoint {
            job: 7,
            tenant: 1,
            attempt: 1,
            total: 10,
            done: 6,
            plan: sp,
            grid: GridPayload::from_grid(&snap),
            power: None,
        };
        let path = Checkpoint::path_for(&journal, 7);
        ck.save(&path, false).expect("sidecar writes");
        let fresh_input = || RetryInput {
            grid: Grid::new2d(8, 8),
            power: None,
            iterations: None,
            base_iter: 0,
            total: 10,
        };
        let mut input = fresh_input();
        assert!(refresh_from_sidecar(&cfg, 7, 1, &mut input));
        assert_eq!(input.base_iter, 6);
        assert_eq!(input.grid.data(), snap.data(), "retry restarts from the snapshot");
        // Rejected: wrong job id path (no sidecar), wrong tenant, stale
        // progress, mismatched total — each leaves the input untouched.
        let mut input = fresh_input();
        assert!(!refresh_from_sidecar(&cfg, 8, 1, &mut input));
        assert!(!refresh_from_sidecar(&cfg, 7, 2, &mut input));
        input.total = 11;
        assert!(!refresh_from_sidecar(&cfg, 7, 1, &mut input));
        input.total = 10;
        input.base_iter = 6;
        assert!(!refresh_from_sidecar(&cfg, 7, 1, &mut input), "not beyond what we have");
        assert_eq!(input.grid.data(), Grid::new2d(8, 8).data());
        let _ = std::fs::remove_file(&path);
    }
}
