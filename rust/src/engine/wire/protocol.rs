//! The wire protocol: the job-lifecycle messages over the shared frame
//! codec.
//!
//! One frame = a 4-byte big-endian length followed by that many bytes of
//! UTF-8 JSON; the codec itself (framing, base64, grid payloads) lives in
//! [`super::frame`] and is re-exported here, so this module is purely the
//! [`Request`]/[`Response`] message vocabulary, serialized through
//! [`crate::util::json::Json`] on the in-tree substrate (no external
//! crates).
//!
//! Every decode path returns a typed [`WireError`]; torn, oversized and
//! garbage frames are *rejections*, never panics or hangs (property-tested
//! in `rust/tests/wire_protocol.rs`). Grid payloads travel as base64 of
//! the little-endian f32 bytes, so results round-trip bit-exactly — the
//! end-to-end wire tests assert bit-equality with the serial oracle.

use std::fmt;

use crate::coordinator::{Plan, PlanBuilder};
use crate::stencil::StencilRegistry;
use crate::util::json::Json;

use super::super::{Backend, EngineError};
use super::frame::{
    opt_u64, opt_usize, opt_usize_arr, req_str, req_u64, req_usize, req_usize_arr, u64_json,
    usize_arr,
};
use super::queue::JobState;

// Historical home of the codec: keep the old import paths working so the
// frontend, client and tests are agnostic to the frame.rs extraction.
pub use super::frame::{
    b64_decode, b64_encode, encode_frame, read_frame, write_frame, GridPayload,
    MAX_FRAME_BYTES,
};

/// Everything the wire layer can fail with. `Closed` is the clean
/// end-of-stream (EOF exactly at a frame boundary); everything else is a
/// defect in the peer or the transport.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// Transport error (with the `std::io::ErrorKind` for callers that
    /// need to distinguish timeouts from hard failures).
    Io(std::io::ErrorKind, String),
    /// EOF exactly at a frame boundary — the peer hung up cleanly.
    Closed,
    /// EOF (or a dead deadline) mid-frame: `got` of `want` body bytes
    /// arrived.
    Torn { got: usize, want: usize },
    /// The length prefix exceeds [`MAX_FRAME_BYTES`]; the body was never
    /// read.
    Oversized { len: usize, max: usize },
    /// The body was not valid UTF-8 JSON.
    BadJson(String),
    /// The JSON was well-formed but not a valid protocol message.
    BadMessage(String),
    /// The server answered with a typed protocol error.
    Server { kind: ErrorKind, message: String },
    /// An `open` was rejected by the server-side static auditor.
    /// `report` is the serialized [`crate::analysis::AuditReport`] JSON
    /// so clients can render every diagnostic (code, span, message).
    Rejected { message: String, report: String },
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Io(kind, msg) => write!(f, "wire i/o error ({kind:?}): {msg}"),
            WireError::Closed => f.write_str("connection closed"),
            WireError::Torn { got, want } => {
                write!(f, "torn frame: got {got} of {want} bytes")
            }
            WireError::Oversized { len, max } => {
                write!(f, "oversized frame: {len} bytes exceeds the {max}-byte cap")
            }
            WireError::BadJson(msg) => write!(f, "frame body is not JSON: {msg}"),
            WireError::BadMessage(msg) => write!(f, "bad protocol message: {msg}"),
            WireError::Server { kind, message } => {
                write!(f, "server error [{}]: {message}", kind.code())
            }
            WireError::Rejected { message, .. } => {
                write!(f, "open rejected by static audit: {message}")
            }
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> WireError {
        WireError::Io(e.kind(), e.to_string())
    }
}

/// Typed protocol error categories carried by [`Response::Error`]. The
/// quota variants are the backpressure signal the fault battery exercises.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorKind {
    /// Frame-level defect (torn/oversized/garbage) reported back before
    /// the connection is dropped.
    BadFrame,
    /// Well-formed frame, invalid request (unknown type, missing field).
    BadRequest,
    /// The named session does not exist (never opened, or closed).
    UnknownSession,
    /// The named job id was never accepted by this server (or journal).
    UnknownJob,
    /// Per-tenant queued-job quota exceeded — retry after jobs drain.
    QuotaJobs,
    /// Per-tenant queued-cells quota exceeded — retry after jobs drain.
    QuotaCells,
    /// The plan (or an inline stencil program) failed validation.
    Plan,
    /// The engine rejected the submission (shape/power/schedule).
    Engine,
    /// The job's deadline passed before it finished (queued jobs fail
    /// fast; active jobs cancel-drain).
    DeadlineExceeded,
    /// The server is shutting down.
    Shutdown,
}

impl ErrorKind {
    pub fn code(self) -> &'static str {
        match self {
            ErrorKind::BadFrame => "bad-frame",
            ErrorKind::BadRequest => "bad-request",
            ErrorKind::UnknownSession => "unknown-session",
            ErrorKind::UnknownJob => "unknown-job",
            ErrorKind::QuotaJobs => "quota-jobs",
            ErrorKind::QuotaCells => "quota-cells",
            ErrorKind::Plan => "plan",
            ErrorKind::Engine => "engine",
            ErrorKind::DeadlineExceeded => "deadline-exceeded",
            ErrorKind::Shutdown => "shutdown",
        }
    }

    pub fn parse(code: &str) -> Option<ErrorKind> {
        Some(match code {
            "bad-frame" => ErrorKind::BadFrame,
            "bad-request" => ErrorKind::BadRequest,
            "unknown-session" => ErrorKind::UnknownSession,
            "unknown-job" => ErrorKind::UnknownJob,
            "quota-jobs" => ErrorKind::QuotaJobs,
            "quota-cells" => ErrorKind::QuotaCells,
            "plan" => ErrorKind::Plan,
            "engine" => ErrorKind::Engine,
            "deadline-exceeded" => ErrorKind::DeadlineExceeded,
            "shutdown" => ErrorKind::Shutdown,
            _ => return None,
        })
    }
}

// -------------------------------------------------------------- plan spec

/// The open-session plan description: everything [`PlanBuilder`] needs,
/// expressed in names and numbers so any client language can speak it.
/// The stencil is referenced by registry name; inline programs ride in
/// the `programs` field of [`Request::Open`] (same JSON schema as
/// `--stencil-file`) and are registered before the plan is built.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanSpec {
    pub stencil: String,
    pub grid_dims: Vec<usize>,
    pub iterations: usize,
    /// [`Backend::parse`] spec string (`scalar`, `vec:N`, `stream:N`).
    pub backend: String,
    pub tile: Option<Vec<usize>>,
    pub coeffs: Option<Vec<f32>>,
    pub step_sizes: Option<Vec<usize>>,
    pub workers: Option<usize>,
    /// Opt-in numeric circuit breaker: trip a typed `NonFinite` failure
    /// when a tile result contains NaN/Inf instead of propagating poison.
    pub guard_nonfinite: Option<bool>,
    /// Requested cluster shard count. `Some(n > 1)` asks the server to
    /// route jobs through the sharded cluster path with (up to) `n`
    /// worker processes; `Some(1)` pins the session to the local pool
    /// even when the cost-based router would shard; `None` lets the
    /// server decide from the configured routing threshold and
    /// [`crate::model::PerfModel::cluster_mcells`].
    pub shards: Option<usize>,
}

impl PlanSpec {
    /// Describe an existing in-process plan (client-side convenience; the
    /// wire-vs-inproc ablation uses this to run identical plans).
    pub fn from_plan(plan: &Plan) -> PlanSpec {
        PlanSpec {
            stencil: plan.stencil.name().to_string(),
            grid_dims: plan.grid_dims.clone(),
            iterations: plan.iterations,
            backend: plan.backend.to_string(),
            tile: Some(plan.tile.clone()),
            coeffs: Some(plan.coeffs.clone()),
            step_sizes: Some(plan.step_sizes.clone()),
            workers: plan.workers,
            guard_nonfinite: plan.guard_nonfinite.then_some(true),
            shards: None,
        }
    }

    /// Resolve the spec against the stencil registry and build the plan.
    pub fn build(&self) -> Result<Plan, EngineError> {
        let id = StencilRegistry::lookup(&self.stencil).ok_or_else(|| {
            EngineError::InvalidPlan(format!(
                "unknown stencil {:?} (register it inline via the open request's \
                 `programs` field)",
                self.stencil
            ))
        })?;
        let backend = Backend::parse(&self.backend)?;
        let mut b = PlanBuilder::new(id)
            .grid_dims(self.grid_dims.clone())
            .iterations(self.iterations)
            .backend(backend);
        if let Some(tile) = &self.tile {
            b = b.tile(tile.clone());
        }
        if let Some(coeffs) = &self.coeffs {
            b = b.coeffs(coeffs.clone());
        }
        if let Some(sizes) = &self.step_sizes {
            b = b.step_sizes(sizes.clone());
        }
        if let Some(w) = self.workers {
            b = b.workers(w);
        }
        if self.guard_nonfinite == Some(true) {
            b = b.guard_nonfinite(true);
        }
        b.build().map_err(|e| EngineError::InvalidPlan(format!("{e:#}")))
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("stencil", Json::from(self.stencil.clone())),
            ("grid_dims", usize_arr(&self.grid_dims)),
            ("iterations", Json::from(self.iterations)),
            ("backend", Json::from(self.backend.clone())),
        ];
        if let Some(tile) = &self.tile {
            pairs.push(("tile", usize_arr(tile)));
        }
        if let Some(coeffs) = &self.coeffs {
            pairs.push((
                "coeffs",
                Json::Arr(coeffs.iter().map(|&c| Json::from(c as f64)).collect()),
            ));
        }
        if let Some(sizes) = &self.step_sizes {
            pairs.push(("step_sizes", usize_arr(sizes)));
        }
        if let Some(w) = self.workers {
            pairs.push(("workers", Json::from(w)));
        }
        if let Some(g) = self.guard_nonfinite {
            pairs.push(("guard_nonfinite", Json::from(g)));
        }
        if let Some(s) = self.shards {
            pairs.push(("shards", Json::from(s)));
        }
        Json::obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<PlanSpec, WireError> {
        let coeffs = match v.get("coeffs") {
            None => None,
            Some(c) => Some(
                c.as_arr()
                    .ok_or_else(|| WireError::BadMessage("coeffs must be an array".into()))?
                    .iter()
                    .map(|x| x.as_f64().map(|f| f as f32))
                    .collect::<Option<Vec<f32>>>()
                    .ok_or_else(|| WireError::BadMessage("coeffs must be numbers".into()))?,
            ),
        };
        Ok(PlanSpec {
            stencil: req_str(v, "stencil")?.to_string(),
            grid_dims: req_usize_arr(v, "grid_dims")?,
            iterations: req_usize(v, "iterations")?,
            backend: req_str(v, "backend")?.to_string(),
            tile: opt_usize_arr(v, "tile")?,
            coeffs,
            step_sizes: opt_usize_arr(v, "step_sizes")?,
            workers: opt_usize(v, "workers")?,
            guard_nonfinite: v.get("guard_nonfinite").and_then(Json::as_bool),
            shards: opt_usize(v, "shards")?,
        })
    }
}

// --------------------------------------------------------------- messages

/// Client → server messages: the full job lifecycle.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Open a tenant session: a plan spec plus optional inline stencil
    /// programs (the JSON `--stencil-file` accepts), registered
    /// idempotently-by-content before the plan is built.
    Open { plan: PlanSpec, programs: Vec<Json> },
    /// Submit one workload into an open session. The job id in the
    /// response is stable across reconnects (and, via the journal,
    /// across server restarts).
    Submit {
        session: u64,
        grid: GridPayload,
        power: Option<GridPayload>,
        iterations: Option<usize>,
        /// Optional wall-clock budget: the job must be terminal within
        /// this many milliseconds of acceptance or it fails with
        /// [`ErrorKind::DeadlineExceeded`] (queued → fail fast, active →
        /// cancel-drain).
        deadline_ms: Option<u64>,
    },
    /// Non-blocking status probe by job id.
    Poll { job: u64 },
    /// Block server-side until the job is terminal or `timeout_ms`
    /// elapses; a finished job's result rides back in the response.
    Wait { job: u64, timeout_ms: u64 },
    /// Ask the server to abandon a job (idempotent; completion races are
    /// benign).
    Cancel { job: u64 },
    /// Per-tenant wire metrics + engine scheduler stats.
    Stats { session: u64 },
    /// Close a session. Outstanding jobs keep draining and stay
    /// poll-able by id; new submits are rejected.
    Close { session: u64 },
    /// Liveness probe.
    Ping,
}

impl Request {
    pub fn to_json(&self) -> Json {
        match self {
            Request::Open { plan, programs } => {
                let mut pairs =
                    vec![("type", Json::from("open")), ("plan", plan.to_json())];
                if !programs.is_empty() {
                    pairs.push(("programs", Json::Arr(programs.clone())));
                }
                Json::obj(pairs)
            }
            Request::Submit { session, grid, power, iterations, deadline_ms } => {
                let mut pairs = vec![
                    ("type", Json::from("submit")),
                    ("session", u64_json(*session)),
                    ("grid", grid.to_json()),
                ];
                if let Some(p) = power {
                    pairs.push(("power", p.to_json()));
                }
                if let Some(i) = iterations {
                    pairs.push(("iterations", Json::from(*i)));
                }
                if let Some(d) = deadline_ms {
                    pairs.push(("deadline_ms", u64_json(*d)));
                }
                Json::obj(pairs)
            }
            Request::Poll { job } => {
                Json::obj(vec![("type", Json::from("poll")), ("job", u64_json(*job))])
            }
            Request::Wait { job, timeout_ms } => Json::obj(vec![
                ("type", Json::from("wait")),
                ("job", u64_json(*job)),
                ("timeout_ms", u64_json(*timeout_ms)),
            ]),
            Request::Cancel { job } => {
                Json::obj(vec![("type", Json::from("cancel")), ("job", u64_json(*job))])
            }
            Request::Stats { session } => Json::obj(vec![
                ("type", Json::from("stats")),
                ("session", u64_json(*session)),
            ]),
            Request::Close { session } => Json::obj(vec![
                ("type", Json::from("close")),
                ("session", u64_json(*session)),
            ]),
            Request::Ping => Json::obj(vec![("type", Json::from("ping"))]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Request, WireError> {
        match req_str(v, "type")? {
            "open" => {
                let plan = PlanSpec::from_json(
                    v.get("plan")
                        .ok_or_else(|| WireError::BadMessage("open needs a plan".into()))?,
                )?;
                let programs = match v.get("programs") {
                    None => Vec::new(),
                    Some(p) => p
                        .as_arr()
                        .ok_or_else(|| {
                            WireError::BadMessage("programs must be an array".into())
                        })?
                        .to_vec(),
                };
                Ok(Request::Open { plan, programs })
            }
            "submit" => Ok(Request::Submit {
                session: req_u64(v, "session")?,
                grid: GridPayload::from_json(v.get("grid").ok_or_else(|| {
                    WireError::BadMessage("submit needs a grid".into())
                })?)?,
                power: match v.get("power") {
                    None => None,
                    Some(p) => Some(GridPayload::from_json(p)?),
                },
                iterations: opt_usize(v, "iterations")?,
                deadline_ms: opt_u64(v, "deadline_ms")?,
            }),
            "poll" => Ok(Request::Poll { job: req_u64(v, "job")? }),
            "wait" => Ok(Request::Wait {
                job: req_u64(v, "job")?,
                timeout_ms: req_u64(v, "timeout_ms")?,
            }),
            "cancel" => Ok(Request::Cancel { job: req_u64(v, "job")? }),
            "stats" => Ok(Request::Stats { session: req_u64(v, "session")? }),
            "close" => Ok(Request::Close { session: req_u64(v, "session")? }),
            "ping" => Ok(Request::Ping),
            other => Err(WireError::BadMessage(format!("unknown request type {other:?}"))),
        }
    }
}

/// Server → client messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Opened { session: u64 },
    Accepted { job: u64 },
    /// Job status snapshot (poll, cancel ack, or a wait that timed out).
    Status { job: u64, state: JobState, attempts: u32 },
    /// A finished job's output. Returned once per job (the result is
    /// fetched-once); later waits see `Status{Done}`.
    Result { job: u64, grid: GridPayload, attempts: u32, report: Json },
    Stats { session: u64, stats: Json },
    Closed { session: u64 },
    /// Liveness + health snapshot: server uptime, pool size, journal-level
    /// job counts, whether chaos injection is armed, and the shard-level
    /// cluster counters (shard workers currently running, halo cells
    /// overlapped with compute so far, shard-loss retries healed).
    Pong {
        uptime_ms: u64,
        workers: u64,
        jobs_queued: u64,
        jobs_active: u64,
        chaos: bool,
        shards_active: u64,
        halo_overlapped: u64,
        shard_retries: u64,
    },
    /// An `open` whose plan failed the server-side static audit: the
    /// message summarizes, `diagnostics` is the full serialized
    /// [`crate::analysis::AuditReport`] (subject, counts, per-diagnostic
    /// code/name/severity/span/message).
    Rejected { message: String, diagnostics: Json },
    Error { kind: ErrorKind, message: String },
}

impl Response {
    pub fn to_json(&self) -> Json {
        match self {
            Response::Opened { session } => Json::obj(vec![
                ("type", Json::from("opened")),
                ("session", u64_json(*session)),
            ]),
            Response::Accepted { job } => Json::obj(vec![
                ("type", Json::from("accepted")),
                ("job", u64_json(*job)),
            ]),
            Response::Status { job, state, attempts } => Json::obj(vec![
                ("type", Json::from("status")),
                ("job", u64_json(*job)),
                ("state", state.to_json()),
                ("attempts", Json::from(*attempts as usize)),
            ]),
            Response::Result { job, grid, attempts, report } => Json::obj(vec![
                ("type", Json::from("result")),
                ("job", u64_json(*job)),
                ("grid", grid.to_json()),
                ("attempts", Json::from(*attempts as usize)),
                ("report", report.clone()),
            ]),
            Response::Stats { session, stats } => Json::obj(vec![
                ("type", Json::from("stats")),
                ("session", u64_json(*session)),
                ("stats", stats.clone()),
            ]),
            Response::Closed { session } => Json::obj(vec![
                ("type", Json::from("closed")),
                ("session", u64_json(*session)),
            ]),
            Response::Pong {
                uptime_ms,
                workers,
                jobs_queued,
                jobs_active,
                chaos,
                shards_active,
                halo_overlapped,
                shard_retries,
            } => Json::obj(vec![
                ("type", Json::from("pong")),
                ("uptime_ms", u64_json(*uptime_ms)),
                ("workers", u64_json(*workers)),
                ("jobs_queued", u64_json(*jobs_queued)),
                ("jobs_active", u64_json(*jobs_active)),
                ("chaos", Json::from(*chaos)),
                ("shards_active", u64_json(*shards_active)),
                ("halo_overlapped", u64_json(*halo_overlapped)),
                ("shard_retries", u64_json(*shard_retries)),
            ]),
            Response::Rejected { message, diagnostics } => Json::obj(vec![
                ("type", Json::from("rejected")),
                ("message", Json::from(message.clone())),
                ("diagnostics", diagnostics.clone()),
            ]),
            Response::Error { kind, message } => Json::obj(vec![
                ("type", Json::from("error")),
                ("kind", Json::from(kind.code())),
                ("message", Json::from(message.clone())),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Response, WireError> {
        match req_str(v, "type")? {
            "opened" => Ok(Response::Opened { session: req_u64(v, "session")? }),
            "accepted" => Ok(Response::Accepted { job: req_u64(v, "job")? }),
            "status" => Ok(Response::Status {
                job: req_u64(v, "job")?,
                state: JobState::from_json(v.get("state").ok_or_else(|| {
                    WireError::BadMessage("status needs a state".into())
                })?)
                .map_err(WireError::BadMessage)?,
                attempts: req_u64(v, "attempts")? as u32,
            }),
            "result" => Ok(Response::Result {
                job: req_u64(v, "job")?,
                grid: GridPayload::from_json(v.get("grid").ok_or_else(|| {
                    WireError::BadMessage("result needs a grid".into())
                })?)?,
                attempts: req_u64(v, "attempts")? as u32,
                report: v.get("report").cloned().unwrap_or(Json::Null),
            }),
            "stats" => Ok(Response::Stats {
                session: req_u64(v, "session")?,
                stats: v.get("stats").cloned().unwrap_or(Json::Null),
            }),
            "closed" => Ok(Response::Closed { session: req_u64(v, "session")? }),
            // Tolerant decode: health fields default to zero/false so a
            // newer client still parses an older server's bare pong.
            "pong" => Ok(Response::Pong {
                uptime_ms: opt_u64(v, "uptime_ms")?.unwrap_or(0),
                workers: opt_u64(v, "workers")?.unwrap_or(0),
                jobs_queued: opt_u64(v, "jobs_queued")?.unwrap_or(0),
                jobs_active: opt_u64(v, "jobs_active")?.unwrap_or(0),
                chaos: v.get("chaos").and_then(Json::as_bool).unwrap_or(false),
                shards_active: opt_u64(v, "shards_active")?.unwrap_or(0),
                halo_overlapped: opt_u64(v, "halo_overlapped")?.unwrap_or(0),
                shard_retries: opt_u64(v, "shard_retries")?.unwrap_or(0),
            }),
            // Tolerant decode: the diagnostics payload defaults to Null
            // so a summary-only rejection still parses.
            "rejected" => Ok(Response::Rejected {
                message: req_str(v, "message")?.to_string(),
                diagnostics: v.get("diagnostics").cloned().unwrap_or(Json::Null),
            }),
            "error" => {
                let code = req_str(v, "kind")?;
                Ok(Response::Error {
                    kind: ErrorKind::parse(code).ok_or_else(|| {
                        WireError::BadMessage(format!("unknown error kind {code:?}"))
                    })?,
                    message: req_str(v, "message")?.to_string(),
                })
            }
            other => {
                Err(WireError::BadMessage(format!("unknown response type {other:?}")))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pong_health_round_trips_and_tolerates_bare_pong() {
        let p = Response::Pong {
            uptime_ms: 1234,
            workers: 8,
            jobs_queued: 2,
            jobs_active: 1,
            chaos: true,
            shards_active: 4,
            halo_overlapped: 4096,
            shard_retries: 1,
        };
        assert_eq!(Response::from_json(&p.to_json()).unwrap(), p);
        // An old-style bare pong still parses, with health zeroed out.
        let bare = Json::obj(vec![("type", Json::from("pong"))]);
        assert_eq!(
            Response::from_json(&bare).unwrap(),
            Response::Pong {
                uptime_ms: 0,
                workers: 0,
                jobs_queued: 0,
                jobs_active: 0,
                chaos: false,
                shards_active: 0,
                halo_overlapped: 0,
                shard_retries: 0,
            }
        );
    }

    #[test]
    fn error_kind_codes_round_trip() {
        for k in [
            ErrorKind::BadFrame,
            ErrorKind::BadRequest,
            ErrorKind::UnknownSession,
            ErrorKind::UnknownJob,
            ErrorKind::QuotaJobs,
            ErrorKind::QuotaCells,
            ErrorKind::Plan,
            ErrorKind::Engine,
            ErrorKind::DeadlineExceeded,
            ErrorKind::Shutdown,
        ] {
            assert_eq!(ErrorKind::parse(k.code()), Some(k));
        }
        assert_eq!(ErrorKind::parse("nope"), None);
    }
}
