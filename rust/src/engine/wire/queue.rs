//! Durable job queue: stable ids, a status ledger, and an append-only
//! JSONL journal with replay-on-restart.
//!
//! Every transition a wire job makes is one line in the journal:
//!
//! ```text
//! {"seq":12,"job":7,"tenant":2,"state":"active","attempts":1,"cells":16384}
//! ```
//!
//! On restart the ledger replays the journal, keeps the *last* record per
//! job, and heals jobs that were non-terminal when the process died to
//! `Failed` (their worker state is gone; the healing record is appended so
//! the journal stays a faithful history). Job-id allocation resumes past
//! the highest replayed id, so ids stay stable across restarts — the
//! kill-and-reconnect fault test leans on exactly this.
//!
//! `attempts` counts attempts *started*: a job accepted but never
//! dispatched has 0; each engine submission bumps it.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, Write};
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Lifecycle states of a wire job. Terminal states never change again —
/// the ledger enforces that, so journal replay is idempotent.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JobState {
    /// Accepted and queued (initially, or between retry attempts).
    Queued,
    /// Submitted to the engine scheduler; a worker may be executing it.
    Active,
    /// Finished successfully; the result is held for one fetch.
    Done,
    /// Out of retry budget (or unrecoverable): the terminal failure.
    Failed { attempts: u32, error: String },
    /// Cancelled by the tenant (or cancel won the race with a failure).
    Cancelled,
}

impl JobState {
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done | JobState::Failed { .. } | JobState::Cancelled)
    }

    pub fn label(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Active => "active",
            JobState::Done => "done",
            JobState::Failed { .. } => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            JobState::Failed { attempts, error } => Json::obj(vec![
                ("label", Json::from("failed")),
                ("attempts", Json::from(*attempts as usize)),
                ("error", Json::from(error.clone())),
            ]),
            other => Json::from(other.label()),
        }
    }

    pub fn from_json(v: &Json) -> Result<JobState, String> {
        if let Some(label) = v.as_str() {
            return Ok(match label {
                "queued" => JobState::Queued,
                "active" => JobState::Active,
                "done" => JobState::Done,
                "cancelled" => JobState::Cancelled,
                other => return Err(format!("unknown job state {other:?}")),
            });
        }
        if v.get("label").and_then(Json::as_str) == Some("failed") {
            let attempts = v
                .get("attempts")
                .and_then(Json::as_usize)
                .ok_or("failed state needs attempts")? as u32;
            let error = v
                .get("error")
                .and_then(Json::as_str)
                .ok_or("failed state needs an error")?
                .to_string();
            return Ok(JobState::Failed { attempts, error });
        }
        Err(format!("unparseable job state: {v}"))
    }
}

/// One job's ledger row: who owns it, where it is, how many attempts have
/// started, and how big it is (for quota accounting after replay).
#[derive(Debug, Clone, PartialEq)]
pub struct JobStatus {
    pub job: u64,
    pub tenant: u64,
    pub state: JobState,
    pub attempts: u32,
    pub cells: u64,
}

impl JobStatus {
    fn to_json(&self, seq: u64) -> Json {
        Json::obj(vec![
            ("seq", Json::Num(seq as f64)),
            ("job", Json::Num(self.job as f64)),
            ("tenant", Json::Num(self.tenant as f64)),
            ("state", self.state.to_json()),
            ("attempts", Json::from(self.attempts as usize)),
            ("cells", Json::Num(self.cells as f64)),
        ])
    }

    fn from_json(v: &Json) -> Result<JobStatus, String> {
        let num = |key: &str| -> Result<u64, String> {
            v.get(key)
                .and_then(Json::as_f64)
                .filter(|n| *n >= 0.0 && n.fract() == 0.0)
                .map(|n| n as u64)
                .ok_or_else(|| format!("journal record missing {key:?}"))
        };
        Ok(JobStatus {
            job: num("job")?,
            tenant: num("tenant")?,
            state: JobState::from_json(
                v.get("state").ok_or("journal record missing state")?,
            )?,
            attempts: num("attempts")? as u32,
            cells: num("cells")?,
        })
    }
}

/// The status ledger. In-memory map of latest status per job, optionally
/// mirrored to an append-only JSONL journal (one `fsync`-free `flush` per
/// record — durability against process death, not power loss, which is
/// the failure mode the fault battery models).
pub struct JobLedger {
    jobs: BTreeMap<u64, JobStatus>,
    next_job: u64,
    seq: u64,
    sink: Option<(PathBuf, File)>,
    /// Jobs healed to Failed during replay (were non-terminal at crash).
    pub healed: Vec<u64>,
}

impl JobLedger {
    /// Ledger with no journal: statuses live and die with the process.
    pub fn in_memory() -> JobLedger {
        JobLedger { jobs: BTreeMap::new(), next_job: 1, seq: 0, sink: None, healed: Vec::new() }
    }

    /// Open (or create) a journal file, replaying any existing records.
    /// A torn final line — the crash wrote half a record — is tolerated
    /// and dropped; everything before it is kept. Jobs left non-terminal
    /// by the crash are healed to `Failed` and the healing records
    /// appended, so a reconnecting client polling a job id always gets a
    /// truthful terminal answer.
    pub fn open(path: &Path) -> std::io::Result<JobLedger> {
        let mut ledger = JobLedger::in_memory();
        if path.exists() {
            let reader = BufReader::new(File::open(path)?);
            for line in reader.lines() {
                let line = line?;
                if line.trim().is_empty() {
                    continue;
                }
                // Torn tail: a record the dying process never finished.
                // Anything unparseable mid-file is also skipped — the
                // journal is append-only, so later records supersede it.
                let Ok(v) = Json::parse(&line) else { continue };
                let Ok(status) = JobStatus::from_json(&v) else { continue };
                if let Some(seq) =
                    v.get("seq").and_then(Json::as_f64).map(|n| n as u64)
                {
                    ledger.seq = ledger.seq.max(seq);
                }
                ledger.next_job = ledger.next_job.max(status.job + 1);
                ledger.jobs.insert(status.job, status);
            }
        }
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        ledger.sink = Some((path.to_path_buf(), file));
        // Heal: any job that was mid-flight when the last process died
        // can never complete — its worker state is gone.
        let orphans: Vec<u64> = ledger
            .jobs
            .iter()
            .filter(|(_, s)| !s.state.is_terminal())
            .map(|(&id, _)| id)
            .collect();
        for id in orphans {
            let mut status = ledger.jobs[&id].clone();
            status.state = JobState::Failed {
                attempts: status.attempts,
                error: "interrupted by server restart".to_string(),
            };
            ledger.append(&status)?;
            ledger.jobs.insert(id, status);
            ledger.healed.push(id);
        }
        Ok(ledger)
    }

    /// Path of the journal file, if this ledger is durable.
    pub fn journal_path(&self) -> Option<&Path> {
        self.sink.as_ref().map(|(p, _)| p.as_path())
    }

    /// Allocate the next stable job id.
    pub fn allocate(&mut self) -> u64 {
        let id = self.next_job;
        self.next_job += 1;
        id
    }

    fn append(&mut self, status: &JobStatus) -> std::io::Result<()> {
        if let Some((_, file)) = &mut self.sink {
            self.seq += 1;
            writeln!(file, "{}", status.to_json(self.seq))?;
            file.flush()?;
        }
        Ok(())
    }

    /// Record a transition. Terminal states are sticky: a late transition
    /// on an already-terminal job is ignored (completion/cancel races are
    /// resolved by whoever records first). Journal write failures are
    /// swallowed — a full disk must not take down job execution — but the
    /// in-memory ledger always advances.
    pub fn record(&mut self, status: JobStatus) {
        if let Some(prev) = self.jobs.get(&status.job) {
            if prev.state.is_terminal() {
                return;
            }
        }
        let _ = self.append(&status);
        self.jobs.insert(status.job, status);
    }

    /// Latest status of a job, if this ledger has ever seen it.
    pub fn status(&self, job: u64) -> Option<&JobStatus> {
        self.jobs.get(&job)
    }

    /// All known jobs (tests and ops tooling).
    pub fn jobs(&self) -> impl Iterator<Item = &JobStatus> {
        self.jobs.values()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn status(job: u64, state: JobState, attempts: u32) -> JobStatus {
        JobStatus { job, tenant: 1, state, attempts, cells: 64 }
    }

    #[test]
    fn state_json_round_trips() {
        for s in [
            JobState::Queued,
            JobState::Active,
            JobState::Done,
            JobState::Failed { attempts: 3, error: "boom".into() },
            JobState::Cancelled,
        ] {
            assert_eq!(JobState::from_json(&s.to_json()).unwrap(), s);
        }
        assert!(JobState::from_json(&Json::from("nope")).is_err());
    }

    #[test]
    fn terminal_states_are_sticky() {
        let mut l = JobLedger::in_memory();
        let id = l.allocate();
        l.record(status(id, JobState::Queued, 0));
        l.record(status(id, JobState::Cancelled, 1));
        l.record(status(id, JobState::Done, 1));
        assert_eq!(l.status(id).unwrap().state, JobState::Cancelled);
    }

    #[test]
    fn replay_restores_and_heals() {
        let dir = std::env::temp_dir().join(format!(
            "fstencil-ledger-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("journal.jsonl");
        let _ = std::fs::remove_file(&path);

        {
            let mut l = JobLedger::open(&path).unwrap();
            let a = l.allocate();
            let b = l.allocate();
            l.record(status(a, JobState::Queued, 0));
            l.record(status(a, JobState::Active, 1));
            l.record(status(a, JobState::Done, 1));
            l.record(status(b, JobState::Active, 2));
            // process "dies" here with b non-terminal
        }
        // Simulate a torn final line from the crash.
        {
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(b"{\"seq\":99,\"job\":3,\"tena").unwrap();
        }

        let mut l = JobLedger::open(&path).unwrap();
        assert_eq!(l.status(1).unwrap().state, JobState::Done);
        assert_eq!(
            l.status(2).unwrap().state,
            JobState::Failed { attempts: 2, error: "interrupted by server restart".into() }
        );
        assert_eq!(l.healed, vec![2]);
        // Ids resume past the replayed maximum.
        assert_eq!(l.allocate(), 3);

        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
    }
}
