//! Durable job queue: stable ids, a status ledger, and an append-only
//! JSONL journal with replay-on-restart.
//!
//! Every transition a wire job makes is one line in the journal:
//!
//! ```text
//! {"seq":12,"job":7,"tenant":2,"state":"active","attempts":1,"cells":16384}
//! ```
//!
//! On restart the ledger replays the journal and keeps the *last* record
//! per job. Jobs that were non-terminal when the process died are either
//! *resumed* from a valid checkpoint sidecar (the frontend decides; the
//! ledger records a `Resumed` transition) or healed to `Failed` (their
//! worker state is gone; the healing record is appended so the journal
//! stays a faithful history). Job-id allocation resumes past the highest
//! replayed id, so ids stay stable across restarts — the
//! kill-and-reconnect fault tests lean on exactly this.
//!
//! The journal is compacted on bind once it outgrows a size threshold:
//! the full history is rewritten as one terminal-state snapshot per job
//! (atomic tmp + rename), so a long-lived server's journal stays O(jobs)
//! instead of O(transitions).
//!
//! `attempts` counts attempts *started*: a job accepted but never
//! dispatched has 0; each engine submission bumps it.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use super::super::chaos::{ChaosPlan, FaultKind};
use crate::util::json::Json;

/// Lifecycle states of a wire job. Terminal states never change again —
/// the ledger enforces that, so journal replay is idempotent.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JobState {
    /// Accepted and queued (initially, or between retry attempts).
    Queued,
    /// Submitted to the engine scheduler; a worker may be executing it.
    Active,
    /// Resumed from a checkpoint after a restart: running again, with the
    /// first `from_iter` iterations carried over from the snapshot.
    Resumed { from_iter: usize },
    /// Finished successfully; the result is held for one fetch.
    Done,
    /// Out of retry budget (or unrecoverable): the terminal failure.
    Failed { attempts: u32, error: String },
    /// Cancelled by the tenant (or cancel won the race with a failure).
    Cancelled,
}

impl JobState {
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done | JobState::Failed { .. } | JobState::Cancelled)
    }

    pub fn label(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Active => "active",
            JobState::Resumed { .. } => "resumed",
            JobState::Done => "done",
            JobState::Failed { .. } => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            JobState::Failed { attempts, error } => Json::obj(vec![
                ("label", Json::from("failed")),
                ("attempts", Json::from(*attempts as usize)),
                ("error", Json::from(error.clone())),
            ]),
            JobState::Resumed { from_iter } => Json::obj(vec![
                ("label", Json::from("resumed")),
                ("from_iter", Json::from(*from_iter)),
            ]),
            other => Json::from(other.label()),
        }
    }

    pub fn from_json(v: &Json) -> Result<JobState, String> {
        if let Some(label) = v.as_str() {
            return Ok(match label {
                "queued" => JobState::Queued,
                "active" => JobState::Active,
                "done" => JobState::Done,
                "cancelled" => JobState::Cancelled,
                other => return Err(format!("unknown job state {other:?}")),
            });
        }
        if v.get("label").and_then(Json::as_str) == Some("resumed") {
            let from_iter = v
                .get("from_iter")
                .and_then(Json::as_usize)
                .ok_or("resumed state needs from_iter")?;
            return Ok(JobState::Resumed { from_iter });
        }
        if v.get("label").and_then(Json::as_str) == Some("failed") {
            let attempts = v
                .get("attempts")
                .and_then(Json::as_usize)
                .ok_or("failed state needs attempts")? as u32;
            let error = v
                .get("error")
                .and_then(Json::as_str)
                .ok_or("failed state needs an error")?
                .to_string();
            return Ok(JobState::Failed { attempts, error });
        }
        Err(format!("unparseable job state: {v}"))
    }
}

/// One job's ledger row: who owns it, where it is, how many attempts have
/// started, and how big it is (for quota accounting after replay).
#[derive(Debug, Clone, PartialEq)]
pub struct JobStatus {
    pub job: u64,
    pub tenant: u64,
    pub state: JobState,
    pub attempts: u32,
    pub cells: u64,
}

impl JobStatus {
    fn to_json(&self, seq: u64) -> Json {
        Json::obj(vec![
            ("seq", Json::Num(seq as f64)),
            ("job", Json::Num(self.job as f64)),
            ("tenant", Json::Num(self.tenant as f64)),
            ("state", self.state.to_json()),
            ("attempts", Json::from(self.attempts as usize)),
            ("cells", Json::Num(self.cells as f64)),
        ])
    }

    fn from_json(v: &Json) -> Result<JobStatus, String> {
        let num = |key: &str| -> Result<u64, String> {
            v.get(key)
                .and_then(Json::as_f64)
                .filter(|n| *n >= 0.0 && n.fract() == 0.0)
                .map(|n| n as u64)
                .ok_or_else(|| format!("journal record missing {key:?}"))
        };
        Ok(JobStatus {
            job: num("job")?,
            tenant: num("tenant")?,
            state: JobState::from_json(
                v.get("state").ok_or("journal record missing state")?,
            )?,
            attempts: num("attempts")? as u32,
            cells: num("cells")?,
        })
    }
}

/// The status ledger. In-memory map of latest status per job, optionally
/// mirrored to an append-only JSONL journal (one `fsync`-free `flush` per
/// record — durability against process death, not power loss, which is
/// the failure mode the fault battery models).
pub struct JobLedger {
    jobs: BTreeMap<u64, JobStatus>,
    next_job: u64,
    seq: u64,
    sink: Option<(PathBuf, File)>,
    /// Seeded fault injection for journal IO (JournalFail swallows a
    /// write, JournalShortWrite tears one) — see [`ChaosPlan`].
    chaos: Option<Arc<ChaosPlan>>,
    /// Jobs healed to Failed during replay (were non-terminal at crash).
    pub healed: Vec<u64>,
    /// Jobs resumed from a checkpoint during replay: `(job, from_iter)`.
    pub resumed: Vec<(u64, usize)>,
}

impl JobLedger {
    /// Ledger with no journal: statuses live and die with the process.
    pub fn in_memory() -> JobLedger {
        JobLedger {
            jobs: BTreeMap::new(),
            next_job: 1,
            seq: 0,
            sink: None,
            chaos: None,
            healed: Vec::new(),
            resumed: Vec::new(),
        }
    }

    /// Open (or create) a journal file, replaying any existing records,
    /// *without* healing orphans. A torn final line — the crash wrote
    /// half a record — is tolerated and dropped; everything before it is
    /// kept. The caller inspects [`JobLedger::orphans`] and either
    /// resumes each from its checkpoint ([`JobLedger::mark_resumed`]) or
    /// heals it ([`JobLedger::heal`]).
    pub fn open_deferred(path: &Path) -> std::io::Result<JobLedger> {
        let mut ledger = JobLedger::in_memory();
        if path.exists() {
            let reader = BufReader::new(File::open(path)?);
            for line in reader.lines() {
                let line = line?;
                if line.trim().is_empty() {
                    continue;
                }
                // Torn tail: a record the dying process never finished.
                // Anything unparseable mid-file is also skipped — the
                // journal is append-only, so later records supersede it.
                let Ok(v) = Json::parse(&line) else { continue };
                let Ok(status) = JobStatus::from_json(&v) else { continue };
                if let Some(seq) =
                    v.get("seq").and_then(Json::as_f64).map(|n| n as u64)
                {
                    ledger.seq = ledger.seq.max(seq);
                }
                ledger.next_job = ledger.next_job.max(status.job + 1);
                ledger.jobs.insert(status.job, status);
            }
        }
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        ledger.sink = Some((path.to_path_buf(), file));
        Ok(ledger)
    }

    /// Open (or create) a journal file, replaying any existing records
    /// and healing every orphan to `Failed`, so a reconnecting client
    /// polling a job id always gets a truthful terminal answer. Callers
    /// that can resume from checkpoints use [`JobLedger::open_deferred`]
    /// and triage orphans themselves.
    pub fn open(path: &Path) -> std::io::Result<JobLedger> {
        let mut ledger = JobLedger::open_deferred(path)?;
        for id in ledger.orphans() {
            ledger.heal(id);
        }
        Ok(ledger)
    }

    /// Jobs that were non-terminal when the last process died. Their
    /// worker state is gone; each must be resumed or healed before the
    /// ledger is served to clients.
    pub fn orphans(&self) -> Vec<u64> {
        self.jobs
            .iter()
            .filter(|(_, s)| !s.state.is_terminal())
            .map(|(&id, _)| id)
            .collect()
    }

    /// Heal one orphan to `Failed` (no usable checkpoint — the attempt's
    /// progress is lost). Idempotent; terminal jobs are left alone.
    pub fn heal(&mut self, id: u64) {
        let Some(status) = self.jobs.get(&id) else { return };
        if status.state.is_terminal() {
            return;
        }
        let mut status = status.clone();
        status.state = JobState::Failed {
            attempts: status.attempts,
            error: "interrupted by server restart".to_string(),
        };
        let _ = self.append(&status);
        self.jobs.insert(id, status);
        self.healed.push(id);
    }

    /// Record that an orphan was resumed from a checkpoint at `from_iter`
    /// completed iterations, running as attempt `attempts`. Terminal jobs
    /// are left alone (a late checkpoint file cannot resurrect a job).
    pub fn mark_resumed(&mut self, id: u64, from_iter: usize, attempts: u32) {
        let Some(prev) = self.jobs.get(&id) else { return };
        if prev.state.is_terminal() {
            return;
        }
        let mut status = prev.clone();
        status.state = JobState::Resumed { from_iter };
        status.attempts = attempts;
        let _ = self.append(&status);
        self.jobs.insert(id, status);
        self.resumed.push((id, from_iter));
    }

    /// Rewrite the journal as one latest-state record per job (atomic
    /// tmp + rename), dropping the transition history. Called on bind
    /// when the journal outgrows the rotation threshold; replaying the
    /// compacted journal yields the identical ledger.
    pub fn compact(&mut self) -> std::io::Result<()> {
        let Some((path, _)) = &self.sink else { return Ok(()) };
        let path = path.clone();
        let tmp = PathBuf::from(format!("{}.compact", path.display()));
        {
            let mut f = File::create(&tmp)?;
            let rows: Vec<JobStatus> = self.jobs.values().cloned().collect();
            for row in rows {
                self.seq += 1;
                writeln!(f, "{}", row.to_json(self.seq))?;
            }
            f.flush()?;
        }
        std::fs::rename(&tmp, &path)?;
        let file = OpenOptions::new().create(true).append(true).open(&path)?;
        self.sink = Some((path, file));
        Ok(())
    }

    /// Current journal size in bytes (0 for in-memory ledgers) — the
    /// rotation trigger.
    pub fn journal_bytes(&self) -> u64 {
        self.sink
            .as_ref()
            .and_then(|(p, _)| std::fs::metadata(p).ok())
            .map(|m| m.len())
            .unwrap_or(0)
    }

    /// Stop journaling: later transitions advance in memory only. The
    /// kill-and-rebind tests use this to freeze the on-disk state at the
    /// "crash" instant while the in-process teardown drains normally.
    pub fn freeze(&mut self) {
        self.sink = None;
    }

    /// Arm seeded journal-IO fault injection for every later append.
    pub fn set_chaos(&mut self, plan: Arc<ChaosPlan>) {
        self.chaos = Some(plan);
    }

    /// Path of the journal file, if this ledger is durable.
    pub fn journal_path(&self) -> Option<&Path> {
        self.sink.as_ref().map(|(p, _)| p.as_path())
    }

    /// Allocate the next stable job id.
    pub fn allocate(&mut self) -> u64 {
        let id = self.next_job;
        self.next_job += 1;
        id
    }

    fn append(&mut self, status: &JobStatus) -> std::io::Result<()> {
        if let Some((_, file)) = &mut self.sink {
            self.seq += 1;
            let line = format!("{}\n", status.to_json(self.seq));
            if let Some(ch) = &self.chaos {
                // The write "fails" silently: nothing reaches disk, but
                // the in-memory ledger still advances — the journal is
                // best-effort durability, never a gate on execution.
                if ch.should(FaultKind::JournalFail, status.job, status.attempts, self.seq) {
                    return Ok(());
                }
                // Torn write: half the record, no newline. It merges
                // with the next appended line, and replay drops both.
                if ch.should(
                    FaultKind::JournalShortWrite,
                    status.job,
                    status.attempts,
                    self.seq,
                ) {
                    file.write_all(&line.as_bytes()[..line.len() / 2])?;
                    file.flush()?;
                    return Ok(());
                }
            }
            file.write_all(line.as_bytes())?;
            file.flush()?;
        }
        Ok(())
    }

    /// Record a transition. Terminal states are sticky: a late transition
    /// on an already-terminal job is ignored (completion/cancel races are
    /// resolved by whoever records first). Journal write failures are
    /// swallowed — a full disk must not take down job execution — but the
    /// in-memory ledger always advances.
    pub fn record(&mut self, status: JobStatus) {
        if let Some(prev) = self.jobs.get(&status.job) {
            if prev.state.is_terminal() {
                return;
            }
        }
        let _ = self.append(&status);
        self.jobs.insert(status.job, status);
    }

    /// Latest status of a job, if this ledger has ever seen it.
    pub fn status(&self, job: u64) -> Option<&JobStatus> {
        self.jobs.get(&job)
    }

    /// All known jobs (tests and ops tooling).
    pub fn jobs(&self) -> impl Iterator<Item = &JobStatus> {
        self.jobs.values()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn status(job: u64, state: JobState, attempts: u32) -> JobStatus {
        JobStatus { job, tenant: 1, state, attempts, cells: 64 }
    }

    #[test]
    fn state_json_round_trips() {
        for s in [
            JobState::Queued,
            JobState::Active,
            JobState::Resumed { from_iter: 8 },
            JobState::Done,
            JobState::Failed { attempts: 3, error: "boom".into() },
            JobState::Cancelled,
        ] {
            assert_eq!(JobState::from_json(&s.to_json()).unwrap(), s);
        }
        assert!(JobState::from_json(&Json::from("nope")).is_err());
    }

    #[test]
    fn terminal_states_are_sticky() {
        let mut l = JobLedger::in_memory();
        let id = l.allocate();
        l.record(status(id, JobState::Queued, 0));
        l.record(status(id, JobState::Cancelled, 1));
        l.record(status(id, JobState::Done, 1));
        assert_eq!(l.status(id).unwrap().state, JobState::Cancelled);
    }

    #[test]
    fn replay_restores_and_heals() {
        let dir = std::env::temp_dir().join(format!(
            "fstencil-ledger-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("journal.jsonl");
        let _ = std::fs::remove_file(&path);

        {
            let mut l = JobLedger::open(&path).unwrap();
            let a = l.allocate();
            let b = l.allocate();
            l.record(status(a, JobState::Queued, 0));
            l.record(status(a, JobState::Active, 1));
            l.record(status(a, JobState::Done, 1));
            l.record(status(b, JobState::Active, 2));
            // process "dies" here with b non-terminal
        }
        // Simulate a torn final line from the crash.
        {
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(b"{\"seq\":99,\"job\":3,\"tena").unwrap();
        }

        let mut l = JobLedger::open(&path).unwrap();
        assert_eq!(l.status(1).unwrap().state, JobState::Done);
        assert_eq!(
            l.status(2).unwrap().state,
            JobState::Failed { attempts: 2, error: "interrupted by server restart".into() }
        );
        assert_eq!(l.healed, vec![2]);
        // Ids resume past the replayed maximum.
        assert_eq!(l.allocate(), 3);

        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
    }

    fn tmp_journal(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "fstencil-ledger-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("journal.jsonl");
        let _ = std::fs::remove_file(&path);
        path
    }

    #[test]
    fn deferred_open_leaves_orphans_for_the_caller() {
        let path = tmp_journal("deferred");
        {
            let mut l = JobLedger::open(&path).unwrap();
            let a = l.allocate();
            l.record(status(a, JobState::Active, 1));
        }
        let mut l = JobLedger::open_deferred(&path).unwrap();
        assert_eq!(l.orphans(), vec![1]);
        assert_eq!(l.status(1).unwrap().state, JobState::Active);
        // Resume instead of heal; the record replays on the next open.
        l.mark_resumed(1, 8, 2);
        assert_eq!(l.status(1).unwrap().state, JobState::Resumed { from_iter: 8 });
        assert_eq!(l.status(1).unwrap().attempts, 2);
        assert_eq!(l.resumed, vec![(1, 8)]);
        drop(l);
        // A plain open() heals the (still non-terminal) resumed job.
        let l = JobLedger::open(&path).unwrap();
        assert_eq!(l.healed, vec![1]);
        assert!(matches!(l.status(1).unwrap().state, JobState::Failed { attempts: 2, .. }));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn compaction_shrinks_the_journal_and_replays_identically() {
        let path = tmp_journal("compact");
        let mut l = JobLedger::open(&path).unwrap();
        for _ in 0..8 {
            let id = l.allocate();
            l.record(status(id, JobState::Queued, 0));
            l.record(status(id, JobState::Active, 1));
            l.record(status(id, JobState::Done, 1));
        }
        let before = l.journal_bytes();
        let states: Vec<JobStatus> = l.jobs().cloned().collect();
        l.compact().unwrap();
        let after = l.journal_bytes();
        assert!(after < before, "compaction must shrink: {before} -> {after}");
        // One line per job, and the compacted journal replays to the
        // identical ledger (ids keep allocating past the max).
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 8);
        drop(l);
        let mut l2 = JobLedger::open(&path).unwrap();
        assert_eq!(l2.jobs().cloned().collect::<Vec<_>>(), states);
        assert_eq!(l2.allocate(), 9);
        // The reopened append handle still works post-rename.
        let id = l2.allocate();
        l2.record(status(id, JobState::Queued, 0));
        drop(l2);
        let l3 = JobLedger::open(&path).unwrap();
        assert!(matches!(l3.status(10).unwrap().state, JobState::Failed { .. }));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn chaos_journal_faults_tear_the_disk_not_the_ledger() {
        let path = tmp_journal("chaos");
        // Phase 1: clean writes for job 1.
        let mut l = JobLedger::open(&path).unwrap();
        let a = l.allocate();
        l.record(status(a, JobState::Queued, 0));
        l.record(status(a, JobState::Done, 1));
        // Phase 2: every append short-writes. Job 2's records merge into
        // one unparseable tail; the in-memory ledger still advances.
        l.set_chaos(Arc::new(ChaosPlan::parse("3:short=1").unwrap()));
        let b = l.allocate();
        l.record(status(b, JobState::Queued, 0));
        l.record(status(b, JobState::Done, 1));
        assert_eq!(l.status(b).unwrap().state, JobState::Done);
        drop(l);
        let l2 = JobLedger::open(&path).unwrap();
        assert_eq!(l2.status(a).unwrap().state, JobState::Done);
        assert!(l2.status(b).is_none(), "torn records must not replay");
        let _ = std::fs::remove_file(&path);

        // JournalFail: nothing reaches disk at all.
        let path = tmp_journal("chaos-fail");
        let mut l = JobLedger::open(&path).unwrap();
        l.set_chaos(Arc::new(ChaosPlan::parse("3:journal=1").unwrap()));
        let a = l.allocate();
        l.record(status(a, JobState::Queued, 0));
        l.record(status(a, JobState::Done, 1));
        assert_eq!(l.status(a).unwrap().state, JobState::Done);
        assert_eq!(l.journal_bytes(), 0);
        let _ = std::fs::remove_file(&path);
    }
}
