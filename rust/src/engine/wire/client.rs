//! Typed client for the wire protocol: one blocking request/response
//! RPC per call over a plain [`TcpStream`]. The CLI `client` subcommand,
//! the wire stress/fault tests, and the `wire_vs_inproc` ablation all
//! drive the server through this — so the client doubles as the
//! closed-loop stress driver the ISSUE asks for.

use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::stencil::Grid;
use crate::util::json::Json;

use super::protocol::{
    read_frame, write_frame, ErrorKind, GridPayload, PlanSpec, Request, Response,
    WireError,
};
use super::queue::JobState;

/// What a `wait` came back with.
#[derive(Debug)]
pub enum WaitOutcome {
    /// The job finished and this wait carried the result home.
    Done { grid: Grid, attempts: u32, report: Json },
    /// Not terminal yet (the server-side wait timed out).
    Pending { state: JobState, attempts: u32 },
    /// Terminal without a result: failed, cancelled, or the result was
    /// already fetched by an earlier wait.
    Terminal { state: JobState, attempts: u32 },
}

/// The server's health snapshot, from an extended `ping`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Health {
    pub uptime_ms: u64,
    /// Shared worker-pool size.
    pub workers: u64,
    /// Wire jobs currently queued (between retry attempts).
    pub jobs_queued: u64,
    /// Wire jobs currently running (active or resumed).
    pub jobs_active: u64,
    /// Whether seeded chaos injection is armed on the server.
    pub chaos: bool,
    /// Cluster shard workers currently running for routed jobs.
    pub shards_active: u64,
    /// Halo cells whose exchange overlapped compute on the cluster path.
    pub halo_overlapped: u64,
    /// Shard-loss retry attempts the front door has re-spawned.
    pub shard_retries: u64,
}

/// A connection to a [`super::WireFrontend`]. Sessions are server-side
/// state keyed by id, not connection state — a client may drop the
/// socket, reconnect, and keep using its session and job ids (the
/// kill-and-reconnect fault test does exactly that).
pub struct WireClient {
    stream: TcpStream,
}

impl WireClient {
    /// Connect with the default 300 s read timeout — generous because a
    /// server-side `wait` can legitimately hold the response for its
    /// full timeout, so this only catches a dead server, not a slow one.
    pub fn connect(addr: &str) -> Result<WireClient, WireError> {
        WireClient::connect_with_timeout(addr, Duration::from_secs(300))
    }

    /// Connect with an explicit per-read timeout (impatient callers:
    /// health probes, soak harnesses racing a kill).
    pub fn connect_with_timeout(
        addr: &str,
        read_timeout: Duration,
    ) -> Result<WireClient, WireError> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(read_timeout))?;
        Ok(WireClient { stream })
    }

    /// One request/response round trip. A server-reported error comes
    /// back as [`WireError::Server`] so callers match on typed kinds.
    pub fn rpc(&mut self, req: &Request) -> Result<Response, WireError> {
        write_frame(&mut self.stream, &req.to_json())?;
        let resp = Response::from_json(&read_frame(&mut self.stream)?)?;
        match resp {
            Response::Error { kind, message } => Err(WireError::Server { kind, message }),
            Response::Rejected { message, diagnostics } => Err(WireError::Rejected {
                message,
                report: diagnostics.to_string(),
            }),
            other => Ok(other),
        }
    }

    pub fn ping(&mut self) -> Result<(), WireError> {
        match self.rpc(&Request::Ping)? {
            Response::Pong { .. } => Ok(()),
            other => Err(unexpected("pong", &other)),
        }
    }

    /// Liveness plus the server's health snapshot.
    pub fn health(&mut self) -> Result<Health, WireError> {
        match self.rpc(&Request::Ping)? {
            Response::Pong {
                uptime_ms,
                workers,
                jobs_queued,
                jobs_active,
                chaos,
                shards_active,
                halo_overlapped,
                shard_retries,
            } => Ok(Health {
                uptime_ms,
                workers,
                jobs_queued,
                jobs_active,
                chaos,
                shards_active,
                halo_overlapped,
                shard_retries,
            }),
            other => Err(unexpected("pong", &other)),
        }
    }

    /// Open a session; returns its stable id.
    pub fn open(&mut self, plan: PlanSpec, programs: Vec<Json>) -> Result<u64, WireError> {
        match self.rpc(&Request::Open { plan, programs })? {
            Response::Opened { session } => Ok(session),
            other => Err(unexpected("opened", &other)),
        }
    }

    /// Submit a grid (optionally with power map / iteration override);
    /// returns the stable job id.
    pub fn submit(
        &mut self,
        session: u64,
        grid: &Grid,
        power: Option<&Grid>,
        iterations: Option<usize>,
    ) -> Result<u64, WireError> {
        self.submit_with_deadline(session, grid, power, iterations, None)
    }

    /// [`WireClient::submit`] with a wall-clock budget: the job must be
    /// terminal within `deadline_ms` of acceptance or it fails with
    /// [`ErrorKind::DeadlineExceeded`] semantics (queued → fail fast,
    /// active → cancel-drain).
    pub fn submit_with_deadline(
        &mut self,
        session: u64,
        grid: &Grid,
        power: Option<&Grid>,
        iterations: Option<usize>,
        deadline_ms: Option<u64>,
    ) -> Result<u64, WireError> {
        let req = Request::Submit {
            session,
            grid: GridPayload::from_grid(grid),
            power: power.map(GridPayload::from_grid),
            iterations,
            deadline_ms,
        };
        match self.rpc(&req)? {
            Response::Accepted { job } => Ok(job),
            other => Err(unexpected("accepted", &other)),
        }
    }

    pub fn poll(&mut self, job: u64) -> Result<(JobState, u32), WireError> {
        match self.rpc(&Request::Poll { job })? {
            Response::Status { state, attempts, .. } => Ok((state, attempts)),
            other => Err(unexpected("status", &other)),
        }
    }

    /// One server-side wait of up to `timeout`.
    pub fn wait(&mut self, job: u64, timeout: Duration) -> Result<WaitOutcome, WireError> {
        let req = Request::Wait { job, timeout_ms: timeout.as_millis() as u64 };
        match self.rpc(&req)? {
            Response::Result { grid, attempts, report, .. } => {
                Ok(WaitOutcome::Done { grid: grid.to_grid()?, attempts, report })
            }
            Response::Status { state, attempts, .. } => {
                if state.is_terminal() {
                    Ok(WaitOutcome::Terminal { state, attempts })
                } else {
                    Ok(WaitOutcome::Pending { state, attempts })
                }
            }
            other => Err(unexpected("result or status", &other)),
        }
    }

    /// Wait until the job is terminal or `deadline` passes; never hangs.
    pub fn wait_result(
        &mut self,
        job: u64,
        deadline: Duration,
    ) -> Result<WaitOutcome, WireError> {
        let end = Instant::now() + deadline;
        loop {
            let left = end.saturating_duration_since(Instant::now());
            if left.is_zero() {
                let (state, attempts) = self.poll(job)?;
                return Ok(WaitOutcome::Pending { state, attempts });
            }
            match self.wait(job, left.min(Duration::from_secs(5)))? {
                WaitOutcome::Pending { .. } => continue,
                terminal => return Ok(terminal),
            }
        }
    }

    /// Request cancellation; returns the job's status at ack time.
    pub fn cancel(&mut self, job: u64) -> Result<(JobState, u32), WireError> {
        match self.rpc(&Request::Cancel { job })? {
            Response::Status { state, attempts, .. } => Ok((state, attempts)),
            other => Err(unexpected("status", &other)),
        }
    }

    /// Per-tenant stats: `{"engine": {...}, "wire": {...}}`.
    pub fn stats(&mut self, session: u64) -> Result<Json, WireError> {
        match self.rpc(&Request::Stats { session })? {
            Response::Stats { stats, .. } => Ok(stats),
            other => Err(unexpected("stats", &other)),
        }
    }

    pub fn close_session(&mut self, session: u64) -> Result<(), WireError> {
        match self.rpc(&Request::Close { session })? {
            Response::Closed { .. } => Ok(()),
            other => Err(unexpected("closed", &other)),
        }
    }

    /// Quota-aware submit helper for closed-loop drivers: on a quota
    /// error, wait for `drain` to reach a terminal state, then retry.
    /// `drain` is the oldest outstanding job the caller tracks.
    pub fn submit_or_drain(
        &mut self,
        session: u64,
        grid: &Grid,
        power: Option<&Grid>,
        iterations: Option<usize>,
        drain: Option<u64>,
    ) -> Result<u64, WireError> {
        match self.submit(session, grid, power, iterations) {
            Err(WireError::Server {
                kind: ErrorKind::QuotaJobs | ErrorKind::QuotaCells,
                ..
            }) => {
                if let Some(old) = drain {
                    let _ = self.wait_result(old, Duration::from_secs(60))?;
                }
                self.submit(session, grid, power, iterations)
            }
            other => other,
        }
    }
}

fn unexpected(wanted: &str, got: &Response) -> WireError {
    WireError::BadMessage(format!("expected a {wanted} response, got {got:?}"))
}
