//! Network front door for the [`super::EngineServer`]: a std-only TCP
//! protocol speaking the full job lifecycle (open / submit / poll / wait
//! / cancel / close), backed by a durable job queue with stable ids, an
//! append-only JSONL status journal, retry-with-max-attempts, and
//! per-tenant quotas.
//!
//! Layering, bottom-up:
//!
//! - [`frame`] — the shared frame codec (u32 length prefix + JSON),
//!   base64, bit-exact grid payloads — also the transport substrate for
//!   the cluster halo protocol ([`crate::cluster`]);
//! - [`protocol`] — typed job-lifecycle requests/responses/errors over
//!   the frame codec;
//! - [`queue`] — job states, status ledger, journal replay + compaction;
//! - [`checkpoint`] — crash-safe mid-job grid snapshots (sidecar files
//!   next to the journal) that let a rebound frontend *resume* a job from
//!   its last barrier instead of restarting it;
//! - [`frontend`] — the TCP server: accept/connection/reaper threads
//!   multiplexing wire tenants onto one [`super::EngineServer`];
//! - [`client`] — the typed blocking client (also the stress driver).
//!
//! See DESIGN.md §3.3 for the frame format and the ledger state machine,
//! and §3.4 for the fault model and recovery matrix.

pub mod checkpoint;
pub mod client;
pub mod frame;
pub mod frontend;
pub mod protocol;
pub mod queue;

pub use checkpoint::Checkpoint;
pub use client::{Health, WaitOutcome, WireClient};
pub use frontend::{ClusterConfig, WireConfig, WireFrontend};
pub use protocol::{ErrorKind, GridPayload, PlanSpec, Request, Response, WireError};
pub use queue::{JobLedger, JobState, JobStatus};
