//! Network front door for the [`super::EngineServer`]: a std-only TCP
//! protocol speaking the full job lifecycle (open / submit / poll / wait
//! / cancel / close), backed by a durable job queue with stable ids, an
//! append-only JSONL status journal, retry-with-max-attempts, and
//! per-tenant quotas.
//!
//! Layering, bottom-up:
//!
//! - [`protocol`] — frame codec (u32 length prefix + JSON), base64 grid
//!   payloads, typed requests/responses/errors;
//! - [`queue`] — job states, status ledger, journal replay;
//! - [`frontend`] — the TCP server: accept/connection/reaper threads
//!   multiplexing wire tenants onto one [`super::EngineServer`];
//! - [`client`] — the typed blocking client (also the stress driver).
//!
//! See DESIGN.md §3.3 for the frame format and the ledger state machine.

pub mod client;
pub mod frontend;
pub mod protocol;
pub mod queue;

pub use client::{WaitOutcome, WireClient};
pub use frontend::{WireConfig, WireFrontend};
pub use protocol::{ErrorKind, GridPayload, PlanSpec, Request, Response, WireError};
pub use queue::{JobLedger, JobState, JobStatus};
