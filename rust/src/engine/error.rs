//! Typed errors for the engine's public boundary.
//!
//! Inside the crate the coordinator/runtime layers use `anyhow`-style
//! context-chained strings; at the [`super::StencilEngine`] boundary every
//! failure is one of these variants so callers can match on *what* went
//! wrong instead of grepping messages. `EngineError` implements
//! `std::error::Error`, so `?` still lifts it into `anyhow::Result`
//! contexts (the CLI does exactly that).

use std::fmt;

use crate::analysis::AuditReport;
use crate::runtime::vec::MAX_PAR_VEC;

/// Everything the engine API can fail with.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EngineError {
    /// A backend spec string did not name a backend.
    UnknownBackend(String),
    /// A lane count was not a power of two in `1..=`[`MAX_PAR_VEC`].
    InvalidParVec(usize),
    /// The plan is internally inconsistent (bad tile, unschedulable
    /// iteration count, missing tile program, ...). Carries the
    /// planner's message.
    InvalidPlan(String),
    /// A submitted grid's shape does not match the session's plan.
    GridShape { expected: Vec<usize>, got: Vec<usize> },
    /// A power grid was required but missing, supplied but unexpected,
    /// or mis-shaped for the session's plan.
    PowerMismatch { expected: bool, got: bool },
    /// A tile program failed while executing (executor-reported).
    Execution(String),
    /// The job was cancelled via [`crate::engine::JobHandle::cancel`]
    /// before it completed.
    Cancelled,
    /// The server is shutting down (or already shut down): the submission
    /// was rejected, or an unfinished job was abandoned after its
    /// in-flight tiles drained.
    Shutdown,
    /// The session's worker pool disappeared mid-submission (a worker
    /// thread exited or a channel closed unexpectedly).
    WorkerLost,
    /// A cluster shard worker process died (or its connection tore)
    /// mid-sweep: the coordinator fails the whole job — a partially
    /// exchanged grid is never returned. `shard` is the dead worker's
    /// rank; `message` carries the transport-level cause.
    ShardLost { shard: usize, message: String },
    /// The job's deadline passed before it finished: queued jobs fail
    /// fast at the next scheduler pass, active jobs stop dispatching and
    /// drain their in-flight tiles first.
    DeadlineExceeded,
    /// The numeric circuit breaker ([`crate::coordinator::Plan`]'s
    /// opt-in `guard_nonfinite`) found a NaN/Inf in a tile result.
    /// `tile` is the block index within the chunk, `iter` the absolute
    /// iteration count the poisoned tile would have completed.
    NonFinite { tile: usize, iter: usize },
    /// The static auditor ([`crate::analysis`]) found `Error`-level
    /// diagnostics at session open or program registration: the full
    /// report is attached so callers can show every finding (code,
    /// span, message) instead of one opaque string.
    Rejected(AuditReport),
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::UnknownBackend(s) => write!(
                f,
                "unknown backend {s:?} (expected scalar, vec[:N] or stream[:N])"
            ),
            EngineError::InvalidParVec(pv) => write!(
                f,
                "par_vec must be a power of two in 1..={MAX_PAR_VEC}, got {pv}"
            ),
            EngineError::InvalidPlan(msg) => write!(f, "invalid plan: {msg}"),
            EngineError::GridShape { expected, got } => {
                write!(f, "grid dims {got:?} do not match the plan's {expected:?}")
            }
            EngineError::PowerMismatch { expected, got } => match (expected, got) {
                (true, false) => f.write_str("stencil requires a power grid, none supplied"),
                (false, true) => f.write_str("stencil takes no power grid, one supplied"),
                _ => f.write_str("power grid dims do not match the plan"),
            },
            EngineError::Execution(msg) => write!(f, "tile execution failed: {msg}"),
            EngineError::Cancelled => f.write_str("job cancelled"),
            EngineError::Shutdown => f.write_str("engine server is shut down"),
            EngineError::WorkerLost => f.write_str("session worker pool exited early"),
            EngineError::ShardLost { shard, message } => {
                write!(f, "cluster shard {shard} lost mid-sweep: {message}")
            }
            EngineError::DeadlineExceeded => f.write_str("job deadline exceeded"),
            EngineError::NonFinite { tile, iter } => write!(
                f,
                "non-finite value (NaN/Inf) in tile {tile} at iteration {iter} \
                 (numeric circuit breaker)"
            ),
            EngineError::Rejected(report) => {
                let codes: Vec<&str> = report.errors().map(|d| d.code).collect();
                write!(
                    f,
                    "plan rejected by static audit of {}: {} error(s) [{}]",
                    report.subject,
                    codes.len(),
                    codes.join(", ")
                )
            }
        }
    }
}

impl std::error::Error for EngineError {}

impl From<anyhow::Error> for EngineError {
    fn from(e: anyhow::Error) -> EngineError {
        EngineError::Execution(format!("{e:#}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_problem() {
        assert!(EngineError::UnknownBackend("foo".into())
            .to_string()
            .contains("foo"));
        assert!(EngineError::InvalidParVec(3).to_string().contains("3"));
        assert!(EngineError::GridShape { expected: vec![64, 64], got: vec![32, 32] }
            .to_string()
            .contains("[32, 32]"));
        assert!(EngineError::DeadlineExceeded.to_string().contains("deadline"));
        let sl = EngineError::ShardLost { shard: 2, message: "connection closed".into() }
            .to_string();
        assert!(sl.contains("shard 2") && sl.contains("connection closed"));
        let nf = EngineError::NonFinite { tile: 3, iter: 8 }.to_string();
        assert!(nf.contains("tile 3") && nf.contains("iteration 8"));
    }

    #[test]
    fn lifts_into_anyhow() {
        fn boundary() -> anyhow::Result<()> {
            Err(EngineError::WorkerLost)?;
            Ok(())
        }
        let e = boundary().unwrap_err();
        assert!(e.to_string().contains("worker pool"));
    }
}
