//! Deficit-round-robin tile scheduling for the multi-tenant server.
//!
//! The paper keeps one deeply pipelined PE chain busy by streaming an
//! unbounded sequence of blocks through it (§3.2, Fig. 2); which block
//! flows next is a pure scheduling decision. [`DeficitRoundRobin`] is that
//! decision for the host [`super::EngineServer`]: clients take turns, each
//! turn banks a `quantum` of *cell-update credit*, and a client may
//! dispatch tiles only while its credit covers the tile's cost
//! (`tile cells × fused steps`). Because credit accrues per rotation, a
//! client with huge 3-D tiles and a client with tiny 2-D tiles are served
//! the same cell-update rate — the big job bursts rarely, the small job
//! often, and neither starves.
//!
//! The structure is deliberately free of threads and clocks so its
//! fairness properties are unit-testable: the server calls
//! [`DeficitRoundRobin::next`] with a `head_cost` probe and performs the
//! actual dispatch itself.

use std::collections::VecDeque;

/// Per-client scheduling account.
#[derive(Debug, Clone, Copy, Default)]
struct Slot {
    /// Banked credit, in cost units (cell updates).
    deficit: u64,
    /// Whether the client currently sits in the service ring.
    queued: bool,
    /// Total cost charged to this client (fairness counter).
    served: u64,
    /// Times the client's credit was replenished (full rotations seen
    /// while it had work it could not yet afford).
    rounds: u64,
    /// Cost the client ran *outside* the pool (cluster-routed jobs).
    /// Observability only: bypassed work never consumes ring credit, but
    /// the fairness ledger should still show where the cells went.
    bypassed: u64,
}

/// Deficit round robin over a set of registered clients.
///
/// `quantum` is the credit granted per rotation. It self-raises to the
/// largest tile cost ever observed (the classic DRR requirement
/// `quantum >= max packet size`), which bounds service latency to at most
/// two full rotations per tile regardless of cost mix.
#[derive(Debug)]
pub struct DeficitRoundRobin {
    quantum: u64,
    slots: Vec<Option<Slot>>,
    ring: VecDeque<usize>,
}

impl DeficitRoundRobin {
    pub fn new(quantum: u64) -> DeficitRoundRobin {
        DeficitRoundRobin { quantum: quantum.max(1), slots: Vec::new(), ring: VecDeque::new() }
    }

    /// Register a client, returning its scheduler id. Freed ids are
    /// reused, so long-lived servers don't grow without bound.
    pub fn register(&mut self) -> usize {
        if let Some(i) = self.slots.iter().position(Option::is_none) {
            self.slots[i] = Some(Slot::default());
            return i;
        }
        self.slots.push(Some(Slot::default()));
        self.slots.len() - 1
    }

    /// Remove a client. Its ring entry (if any) is removed eagerly:
    /// freed ids are reused by [`DeficitRoundRobin::register`], and a
    /// stale ring entry would alias the new client — duplicating its
    /// service turns and breaking the fairness bound.
    pub fn deregister(&mut self, id: usize) {
        if let Some(slot) = self.slots.get_mut(id) {
            *slot = None;
        }
        self.ring.retain(|&x| x != id);
    }

    /// Number of currently registered clients.
    pub fn clients(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Mark a client runnable (it has at least one dispatchable tile).
    /// Idempotent; unknown ids are ignored.
    pub fn enqueue(&mut self, id: usize) {
        if let Some(Some(slot)) = self.slots.get_mut(id) {
            if !slot.queued {
                slot.queued = true;
                self.ring.push_back(id);
            }
        }
    }

    /// Total cost charged to `id` so far (0 for unknown ids).
    pub fn served(&self, id: usize) -> u64 {
        self.slots.get(id).and_then(|s| s.as_ref()).map_or(0, |s| s.served)
    }

    /// Credit-replenishment count for `id` (0 for unknown ids).
    pub fn rounds(&self, id: usize) -> u64 {
        self.slots.get(id).and_then(|s| s.as_ref()).map_or(0, |s| s.rounds)
    }

    /// Record `cost` cell updates the client ran outside the pool (e.g.
    /// a job the front door routed to the cluster). Accounting only —
    /// no ring state changes, no credit is consumed or granted.
    pub fn bypass(&mut self, id: usize, cost: u64) {
        if let Some(Some(slot)) = self.slots.get_mut(id) {
            slot.bypassed = slot.bypassed.saturating_add(cost);
        }
    }

    /// Total bypassed cost recorded for `id` (0 for unknown ids).
    pub fn bypassed(&self, id: usize) -> u64 {
        self.slots.get(id).and_then(|s| s.as_ref()).map_or(0, |s| s.bypassed)
    }

    /// Pick the client whose head tile should be dispatched next and
    /// charge it. `head_cost(id)` returns the cost of the client's next
    /// dispatchable tile, or `None` when it has nothing to dispatch right
    /// now (chunk barrier, empty queue, cancelled) — such clients leave
    /// the ring and forfeit their banked credit (standard DRR: idle flows
    /// don't hoard). Returns `None` when no client has dispatchable work.
    pub fn next(&mut self, mut head_cost: impl FnMut(usize) -> Option<u64>) -> Option<usize> {
        loop {
            let id = *self.ring.front()?;
            let Some(Some(slot)) = self.slots.get_mut(id) else {
                // deregistered while queued: lazy removal
                self.ring.pop_front();
                continue;
            };
            match head_cost(id) {
                None => {
                    slot.queued = false;
                    slot.deficit = 0;
                    self.ring.pop_front();
                }
                Some(cost) => {
                    // DRR soundness: quantum must cover the largest tile,
                    // or a big-tile client could rotate forever.
                    if cost > self.quantum {
                        self.quantum = cost;
                    }
                    if slot.deficit >= cost {
                        slot.deficit -= cost;
                        slot.served += cost;
                        return Some(id);
                    }
                    slot.deficit += self.quantum;
                    slot.rounds += 1;
                    self.ring.rotate_left(1);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive the scheduler over fixed per-client work lists; returns the
    /// dispatch order. `work[id]` is (tile_cost, tiles_remaining).
    fn drain(drr: &mut DeficitRoundRobin, work: &mut [(u64, usize)]) -> Vec<usize> {
        for id in 0..work.len() {
            if work[id].1 > 0 {
                drr.enqueue(id);
            }
        }
        let mut order = Vec::new();
        while let Some(id) =
            drr.next(|id| if work[id].1 > 0 { Some(work[id].0) } else { None })
        {
            work[id].1 -= 1;
            order.push(id);
        }
        order
    }

    #[test]
    fn equal_cost_clients_interleave() {
        let mut drr = DeficitRoundRobin::new(1);
        let a = drr.register();
        let b = drr.register();
        let mut work = [(1u64, 10usize), (1, 10)];
        let order = drain(&mut drr, &mut work);
        assert_eq!(order.len(), 20);
        // Neither client ever runs more than quantum/cost = 1 tile ahead:
        // the order strictly alternates after the first service.
        for w in order.windows(2) {
            assert_ne!(w[0], w[1], "equal-cost clients must alternate: {order:?}");
        }
        assert_eq!(drr.served(a), 10);
        assert_eq!(drr.served(b), 10);
    }

    #[test]
    fn big_tiles_do_not_starve_small_ones() {
        // Client 0 has tiles 16x the cost of client 1's. Served cost must
        // stay within one quantum of each other while both are backlogged.
        let mut drr = DeficitRoundRobin::new(1);
        let big = drr.register();
        let small = drr.register();
        let mut work = [(16u64, 50usize), (1, 800)];
        let mut max_gap = 0i64;
        for id in 0..2 {
            if work[id].1 > 0 {
                drr.enqueue(id);
            }
        }
        let mut dispatched = 0;
        while let Some(id) =
            drr.next(|id| if work[id].1 > 0 { Some(work[id].0) } else { None })
        {
            work[id].1 -= 1;
            dispatched += 1;
            if work[0].1 > 0 && work[1].1 > 0 {
                let gap = drr.served(big) as i64 - drr.served(small) as i64;
                max_gap = max_gap.max(gap.abs());
            }
        }
        assert_eq!(dispatched, 850);
        assert_eq!(drr.served(big), 16 * 50);
        assert_eq!(drr.served(small), 800);
        // quantum self-raises to 16 (the largest tile)
        assert!(max_gap <= 16, "served-cost gap {max_gap} exceeds one quantum");
        assert!(drr.rounds(small) > 0);
    }

    #[test]
    fn three_way_fair_share_of_served_cost() {
        let mut drr = DeficitRoundRobin::new(4);
        for _ in 0..3 {
            drr.register();
        }
        let mut work = [(3u64, 400usize), (7, 400), (5, 400)];
        // stop while all are still backlogged, then compare service.
        for id in 0..3 {
            drr.enqueue(id);
        }
        for _ in 0..300 {
            let id = drr
                .next(|id| if work[id].1 > 0 { Some(work[id].0) } else { None })
                .expect("all clients backlogged");
            work[id].1 -= 1;
        }
        let served: Vec<u64> = (0..3).map(|id| drr.served(id)).collect();
        let (lo, hi) = (served.iter().min().unwrap(), served.iter().max().unwrap());
        // classic DRR bound: within quantum + max_cost (two quanta after
        // the auto-raise to 7) of each other
        assert!(hi - lo <= 7 + 7, "unfair service: {served:?}");
    }

    #[test]
    fn idle_clients_leave_the_ring_and_forfeit_credit() {
        let mut drr = DeficitRoundRobin::new(2);
        let a = drr.register();
        let b = drr.register();
        drr.enqueue(a);
        drr.enqueue(b);
        // b never has work: the first pass removes it.
        let mut a_left = 3usize;
        while let Some(id) = drr.next(|id| {
            if id == a && a_left > 0 {
                Some(1)
            } else {
                None
            }
        }) {
            assert_eq!(id, a);
            a_left -= 1;
        }
        assert_eq!(a_left, 0);
        assert_eq!(drr.served(b), 0);
        // re-enqueue works after going idle (head_cost is a pure probe —
        // it may be called several times per pick)
        drr.enqueue(a);
        let mut left = 1usize;
        let got = drr.next(|id| if id == a && left > 0 { Some(1) } else { None });
        assert_eq!(got, Some(a));
        left -= 1;
        assert_eq!(drr.next(|id| if id == a && left > 0 { Some(1) } else { None }), None);
    }

    /// Regression: deregistering a client that is still QUEUED in the
    /// ring must not leave a stale entry behind — `register` reuses freed
    /// ids, and an aliased entry would grant the new client duplicate
    /// service turns (double fair share).
    #[test]
    fn deregister_while_queued_does_not_alias_reused_id() {
        let mut drr = DeficitRoundRobin::new(1);
        let a = drr.register();
        let b = drr.register();
        drr.enqueue(a);
        drr.enqueue(b);
        // a leaves while still queued; its id is immediately reused.
        drr.deregister(a);
        let c = drr.register();
        assert_eq!(c, a, "freed id is reused");
        drr.enqueue(c);
        // Serve equal-cost work: b and c must alternate strictly — a
        // duplicated ring entry for c would let it serve twice per round.
        let mut work = [(1u64, 6usize), (1, 6)]; // [c, b] by id
        let mut order = Vec::new();
        while let Some(id) =
            drr.next(|id| if work[id].1 > 0 { Some(work[id].0) } else { None })
        {
            work[id].1 -= 1;
            order.push(id);
        }
        assert_eq!(order.len(), 12);
        for w in order.windows(2) {
            assert_ne!(w[0], w[1], "aliased ring entry broke alternation: {order:?}");
        }
        assert_eq!(drr.served(c), 6);
        assert_eq!(drr.served(b), 6);
    }

    #[test]
    fn deregistered_clients_are_skipped() {
        let mut drr = DeficitRoundRobin::new(1);
        let a = drr.register();
        let b = drr.register();
        drr.enqueue(a);
        drr.enqueue(b);
        drr.deregister(a);
        assert_eq!(drr.clients(), 1);
        let mut b_left = 2usize;
        while let Some(id) =
            drr.next(|id| if id == b && b_left > 0 { Some(1) } else { None })
        {
            assert_eq!(id, b);
            b_left -= 1;
        }
        assert_eq!(b_left, 0);
        // freed slot is reused
        assert_eq!(drr.register(), a);
    }

    #[test]
    fn bypassed_cost_is_ledgered_without_touching_fairness() {
        let mut drr = DeficitRoundRobin::new(1);
        let a = drr.register();
        let b = drr.register();
        drr.bypass(a, 1_000_000);
        drr.bypass(a, 500);
        assert_eq!(drr.bypassed(a), 1_000_500);
        assert_eq!(drr.bypassed(b), 0);
        assert_eq!(drr.served(a), 0, "bypassed work is not pool service");
        // Pool fairness is untouched: equal-cost clients still alternate
        // even though a banked a huge bypassed total.
        drr.enqueue(a);
        drr.enqueue(b);
        let mut work = [(1u64, 6usize), (1, 6)];
        let mut order = Vec::new();
        while let Some(id) =
            drr.next(|id| if work[id].1 > 0 { Some(work[id].0) } else { None })
        {
            work[id].1 -= 1;
            order.push(id);
        }
        for w in order.windows(2) {
            assert_ne!(w[0], w[1], "bypass must not skew the ring: {order:?}");
        }
        // Unknown ids are ignored, not panics.
        drr.bypass(99, 5);
        assert_eq!(drr.bypassed(99), 0);
    }

    #[test]
    fn empty_scheduler_returns_none() {
        let mut drr = DeficitRoundRobin::new(8);
        assert_eq!(drr.next(|_| Some(1)), None);
        let id = drr.register();
        // registered but never enqueued: still nothing to schedule
        assert_eq!(drr.next(|_| Some(1)), None);
        drr.enqueue(id);
        assert_eq!(drr.next(|_| None), None);
    }
}
