//! First-class compute-backend selection.
//!
//! The paper programs the FPGA once with a fixed (`par_vec`, `par_time`)
//! configuration and then feeds it kernel invocations; which bitstream is
//! loaded is an explicit, typed choice. [`Backend`] is the host analogue:
//! one enum is the single selection point for the scalar oracle, the
//! vectorized lane backend and the streaming shift-register cascade,
//! replacing the old implicit `stream: bool` + `par_vec > 1` convention
//! that was smeared across `Plan`.

use std::fmt;
use std::str::FromStr;

use crate::runtime::{
    vec::{is_valid_par_vec, DEFAULT_PAR_VEC},
    Executor, HostExecutor, StreamExecutor, VecExecutor,
};

use super::EngineError;

/// Which in-process executor a [`crate::coordinator::Plan`] runs on.
///
/// All three produce bit-identical grids (property-tested); they differ
/// only in how the same f32 operations are scheduled. `parse`/`Display`
/// round-trip (`scalar`, `vec:8`, `stream:4`), and the parser also accepts
/// the bare CLI spellings `vec` / `stream` at the default lane count.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Backend {
    /// The scalar reference oracle ([`HostExecutor`]). The default.
    #[default]
    Scalar,
    /// The vectorized lane backend ([`VecExecutor`]) — Table 1's
    /// `par_vec` compute lanes, one tile sweep per fused step.
    Vec { par_vec: usize },
    /// The streaming shift-register cascade ([`StreamExecutor`]) — the
    /// paper's §3.2 PE chain: one tile sweep per chunk with all fused
    /// steps in flight, rows kernels at `par_vec` lanes.
    Stream { par_vec: usize },
}

impl Backend {
    /// Every selectable backend at its default lane count, in
    /// oracle-first order — handy for verify sweeps and tests.
    pub const ALL: [Backend; 3] = [
        Backend::Scalar,
        Backend::Vec { par_vec: DEFAULT_PAR_VEC },
        Backend::Stream { par_vec: DEFAULT_PAR_VEC },
    ];

    /// Effective lane count (1 for the scalar oracle).
    pub fn par_vec(&self) -> usize {
        match self {
            Backend::Scalar => 1,
            Backend::Vec { par_vec } | Backend::Stream { par_vec } => *par_vec,
        }
    }

    /// Replace the lane count on the lane backends; the scalar oracle is
    /// unaffected (an explicit `--backend scalar` stays scalar even when
    /// `--par-vec` is also given).
    pub fn with_par_vec(self, par_vec: usize) -> Backend {
        match self {
            Backend::Scalar => Backend::Scalar,
            Backend::Vec { .. } => Backend::Vec { par_vec },
            Backend::Stream { .. } => Backend::Stream { par_vec },
        }
    }

    /// Short family name (`scalar`/`vec`/`stream`), without the lane count.
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Vec { .. } => "vec",
            Backend::Stream { .. } => "stream",
        }
    }

    /// Static label used by [`crate::coordinator::ExecReport::backend`]
    /// when a warm [`super::Session`] produced the report.
    pub fn session_label(&self) -> &'static str {
        match self {
            Backend::Scalar => "session-scalar",
            Backend::Vec { .. } => "session-vec",
            Backend::Stream { .. } => "session-stream",
        }
    }

    /// Validate the lane count (a power of two in
    /// `1..=`[`MAX_PAR_VEC`](crate::runtime::vec::MAX_PAR_VEC)).
    pub fn validate(&self) -> Result<(), EngineError> {
        if is_valid_par_vec(self.par_vec()) {
            Ok(())
        } else {
            Err(EngineError::InvalidParVec(self.par_vec()))
        }
    }

    /// Build the executor this backend names — the single point where the
    /// selection becomes a concrete [`Executor`] (the old triple-branch
    /// `Plan::executor` logic lived here and nowhere else).
    pub fn executor(&self) -> Box<dyn Executor + Send + Sync> {
        match self {
            Backend::Scalar => Box::new(HostExecutor::new()),
            Backend::Vec { par_vec } => Box::new(VecExecutor::with_par_vec(*par_vec)),
            Backend::Stream { par_vec } => Box::new(StreamExecutor::with_par_vec(*par_vec)),
        }
    }

    /// Parse a backend spec: `scalar` (alias `host`), `vec`/`stream` at
    /// the default lane count, or `vec:N`/`stream:N` with an explicit
    /// one. Inverse of `Display` for every valid value.
    pub fn parse(s: &str) -> Result<Backend, EngineError> {
        let (family, lanes) = match s.split_once(':') {
            Some((f, l)) => (f, Some(l)),
            None => (s, None),
        };
        let par_vec = match lanes {
            Some(l) => l
                .parse::<usize>()
                .map_err(|_| EngineError::UnknownBackend(s.to_string()))?,
            None => DEFAULT_PAR_VEC,
        };
        let backend = match family {
            "scalar" | "host" => {
                if lanes.is_some() {
                    return Err(EngineError::UnknownBackend(s.to_string()));
                }
                Backend::Scalar
            }
            "vec" => Backend::Vec { par_vec },
            "stream" => Backend::Stream { par_vec },
            _ => return Err(EngineError::UnknownBackend(s.to_string())),
        };
        backend.validate()?;
        Ok(backend)
    }
}

impl fmt::Display for Backend {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Backend::Scalar => f.write_str("scalar"),
            Backend::Vec { par_vec } => write!(f, "vec:{par_vec}"),
            Backend::Stream { par_vec } => write!(f, "stream:{par_vec}"),
        }
    }
}

impl FromStr for Backend {
    type Err = EngineError;

    fn from_str(s: &str) -> Result<Backend, EngineError> {
        Backend::parse(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_cli_spellings() {
        assert_eq!(Backend::parse("scalar").unwrap(), Backend::Scalar);
        assert_eq!(Backend::parse("host").unwrap(), Backend::Scalar);
        assert_eq!(
            Backend::parse("vec").unwrap(),
            Backend::Vec { par_vec: DEFAULT_PAR_VEC }
        );
        assert_eq!(
            Backend::parse("stream:4").unwrap(),
            Backend::Stream { par_vec: 4 }
        );
    }

    #[test]
    fn parse_rejects_junk() {
        for bad in ["", "pjrt", "vec:3", "vec:0", "vec:128", "scalar:2", "vec:x"] {
            assert!(Backend::parse(bad).is_err(), "{bad} should not parse");
        }
    }

    #[test]
    fn display_parse_round_trip() {
        for pv in [1usize, 2, 4, 8, 16, 32, 64] {
            for b in [
                Backend::Scalar,
                Backend::Vec { par_vec: pv },
                Backend::Stream { par_vec: pv },
            ] {
                assert_eq!(Backend::parse(&b.to_string()).unwrap(), b, "{b}");
            }
        }
    }

    #[test]
    fn executor_selection() {
        assert_eq!(Backend::Scalar.executor().backend_name(), "host-scalar");
        assert_eq!(
            Backend::Vec { par_vec: 8 }.executor().backend_name(),
            "host-vec"
        );
        assert_eq!(
            Backend::Stream { par_vec: 1 }.executor().backend_name(),
            "host-stream"
        );
    }

    #[test]
    fn with_par_vec_keeps_scalar_scalar() {
        assert_eq!(Backend::Scalar.with_par_vec(8), Backend::Scalar);
        assert_eq!(
            Backend::Vec { par_vec: 2 }.with_par_vec(16),
            Backend::Vec { par_vec: 16 }
        );
    }
}
