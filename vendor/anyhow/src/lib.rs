//! Offline substrate for the `anyhow` crate (same pattern as the main
//! crate's `util` substrates for `clap`/`criterion`/`proptest`: the build
//! environment has no crates registry, so the subset of the `anyhow` API
//! this project uses is vendored here as a path dependency).
//!
//! Provided surface:
//!
//! * [`Error`] — a context-chained error value. `{}` prints the outermost
//!   message, `{:#}` the full `outer: ...: root` chain, `{:?}` the message
//!   plus a `Caused by:` list, matching `anyhow`'s formatting contract.
//! * [`Result<T>`] — `std::result::Result` with [`Error`] as the default
//!   error type.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! * The [`anyhow!`], [`bail!`] and [`ensure!`] macros.
//!
//! Any `std::error::Error + Send + Sync + 'static` converts into [`Error`]
//! via `?`, preserving its source chain as context lines.

use std::fmt::{self, Debug, Display};

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-chained error. Messages are stored outermost-first; the root
/// cause is the last element.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a single printable message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first (root cause last).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if f.alternate() {
            for cause in &self.chain[1..] {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like real `anyhow`, `Error` deliberately does NOT implement
// `std::error::Error`; that is what makes this blanket conversion coherent
// alongside the identity `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    /// Wrap the error value (or `None`) with an outer context message.
    fn context<C: Display>(self, context: C) -> Result<T>;
    /// Like [`Context::context`], evaluating the message lazily.
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "disk on fire");
    }

    #[test]
    fn context_chains_and_formats() {
        let e: Result<()> = Err(io_err());
        let e = e.context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: disk on fire");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert_eq!(e.root_cause(), "disk on fire");
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: Result<u32, std::io::Error> = Ok(7);
        let v = ok
            .with_context(|| -> String { panic!("must not evaluate on Ok") })
            .unwrap();
        assert_eq!(v, 7);
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("sevens are right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(12).unwrap_err().to_string().contains("12"));
        assert!(f(7).unwrap_err().to_string().contains("sevens"));
        let e = anyhow!("{} and {}", 1, 2);
        assert_eq!(e.to_string(), "1 and 2");
    }

    #[test]
    fn bare_ensure_names_the_condition() {
        fn f() -> Result<()> {
            ensure!(1 + 1 == 3);
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("condition failed"));
    }
}
