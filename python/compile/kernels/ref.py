"""Pure-jnp correctness oracles for the four paper stencils.

These implement the exact cell-update formulas of Table 2 with the paper's
boundary rule (§5.1): "all out-of-bound neighbors of grid cells on the grid
boundaries will fall back on the boundary cell itself", i.e. clamp / edge
replication.

Every oracle operates on a full array (a grid or a tile) and performs ONE
time-step. Multi-step references are built by iterating these.
"""

import jax.numpy as jnp


def _edge_pad2d(x):
    return jnp.pad(x, ((1, 1), (1, 1)), mode="edge")


def _edge_pad3d(x):
    return jnp.pad(x, ((1, 1), (1, 1), (1, 1)), mode="edge")


def neighbors2d(x):
    """Return (c, n, s, w, e) with clamped (edge) out-of-bound neighbors.

    Axis convention: axis 0 is y (north = y-1), axis 1 is x (west = x-1).
    """
    p = _edge_pad2d(x)
    c = p[1:-1, 1:-1]
    n = p[:-2, 1:-1]
    s = p[2:, 1:-1]
    w = p[1:-1, :-2]
    e = p[1:-1, 2:]
    return c, n, s, w, e


def neighbors3d(x):
    """Return (c, n, s, w, e, a, b): axis 0 = z (above = z-1, below = z+1),
    axis 1 = y, axis 2 = x. Edge-clamped."""
    p = _edge_pad3d(x)
    c = p[1:-1, 1:-1, 1:-1]
    a = p[:-2, 1:-1, 1:-1]
    b = p[2:, 1:-1, 1:-1]
    n = p[1:-1, :-2, 1:-1]
    s = p[1:-1, 2:, 1:-1]
    w = p[1:-1, 1:-1, :-2]
    e = p[1:-1, 1:-1, 2:]
    return c, n, s, w, e, a, b


def diffusion2d(x, cc, cn, cs, cw, ce):
    """Diffusion 2D (Table 2): 9 FLOP per cell update."""
    c, n, s, w, e = neighbors2d(x)
    return cc * c + cw * w + ce * e + cs * s + cn * n


def diffusion3d(x, cc, cn, cs, cw, ce, ca, cb):
    """Diffusion 3D (Table 2): 13 FLOP per cell update."""
    c, n, s, w, e, a, b = neighbors3d(x)
    return cc * c + cw * w + ce * e + cs * s + cn * n + cb * b + ca * a


def hotspot2d(temp, power, sdc, rx1, ry1, rz1, amb):
    """Hotspot 2D (Rodinia, Table 2): 15 FLOP per cell update.

    out = c + sdc*(power + (n + s - 2c)*Ry1 + (e + w - 2c)*Rx1 + (amb - c)*Rz1)
    """
    c, n, s, w, e = neighbors2d(temp)
    return c + sdc * (
        power + (n + s - 2.0 * c) * ry1 + (e + w - 2.0 * c) * rx1 + (amb - c) * rz1
    )


def hotspot3d(temp, power, cc, cn, cs, cw, ce, ca, cb, sdc, amb):
    """Hotspot 3D (Rodinia, Table 2): 17 FLOP per cell update.

    out = c*cc + n*cn + s*cs + e*ce + w*cw + a*ca + b*cb + sdc*power + ca*amb
    """
    c, n, s, w, e, a, b = neighbors3d(temp)
    return (
        c * cc
        + n * cn
        + s * cs
        + e * ce
        + w * cw
        + a * ca
        + b * cb
        + sdc * power
        + ca * amb
    )




def diffusion2d_r2(x, cc, cn1, cs1, cw1, ce1, cn2, cs2, cw2, ce2):
    """Radius-2 9-point star diffusion (§8 high-order extension): 17 FLOP."""
    p = jnp.pad(x, ((2, 2), (2, 2)), mode="edge")
    return (
        cc * p[2:-2, 2:-2]
        + cn1 * p[1:-3, 2:-2]
        + cs1 * p[3:-1, 2:-2]
        + cw1 * p[2:-2, 1:-3]
        + ce1 * p[2:-2, 3:-1]
        + cn2 * p[:-4, 2:-2]
        + cs2 * p[4:, 2:-2]
        + cw2 * p[2:-2, :-4]
        + ce2 * p[2:-2, 4:]
    )


def multi_step_ref(kind, steps, x, power=None, coeffs=()):
    """Iterate `steps` single-step oracle applications (new buffer each step,
    as in the paper's double-buffered iteration)."""
    for _ in range(steps):
        if kind == "diffusion2d":
            x = diffusion2d(x, *coeffs)
        elif kind == "diffusion2dr2":
            x = diffusion2d_r2(x, *coeffs)
        elif kind == "diffusion3d":
            x = diffusion3d(x, *coeffs)
        elif kind == "hotspot2d":
            x = hotspot2d(x, power, *coeffs)
        elif kind == "hotspot3d":
            x = hotspot3d(x, power, *coeffs)
        else:
            raise ValueError(f"unknown stencil kind: {kind}")
    return x
