"""L1: Pallas kernels for the paper's compute hot-spot (the stencil update).

One module per stencil family; `ref.py` is the pure-jnp oracle used by the
build-time pytest suite.
"""

from .diffusion import (
    ROW_CHUNK,
    diffusion2d_r2_step,
    diffusion2d_step,
    diffusion3d_step,
)
from .hotspot import hotspot2d_step, hotspot3d_step

__all__ = [
    "ROW_CHUNK",
    "diffusion2d_r2_step",
    "diffusion2d_step",
    "diffusion3d_step",
    "hotspot2d_step",
    "hotspot3d_step",
]
