"""L1 Pallas kernels: Hotspot 2D / 3D (Rodinia) single-step tile update.

Same tiling/streaming scheme as diffusion.py. Hotspot needs a second
external-memory stream — the `power` grid — which the paper also caches in a
(smaller) shift register (§5.1: only the *current* value is needed, so its
shift register holds one row/plane). Here the power tile is a second VMEM
block; no halo is needed on it because only the center tap is read.
"""

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .diffusion import ROW_CHUNK


def _hotspot2d_kernel(t_ref, pw_ref, c_ref, o_ref):
    """One grid-step: ROW_CHUNK rows of the Hotspot-2D update.

    t_ref: (H, W) temperature tile, pw_ref: (H, W) power tile,
    c_ref: (5,) [sdc, rx1, ry1, rz1, amb].
    out = c + sdc*(power + (n+s-2c)*ry1 + (e+w-2c)*rx1 + (amb-c)*rz1)
    """
    i = pl.program_id(0)
    t = t_ref[...]
    pw = pw_ref[...]
    h, w = t.shape
    p = jnp.pad(t, ((1, 1), (1, 1)), mode="edge")
    sdc, rx1, ry1, rz1, amb = (c_ref[k] for k in range(5))
    c = p[1:-1, 1:-1]
    n = p[:-2, 1:-1]
    s = p[2:, 1:-1]
    w_ = p[1:-1, :-2]
    e = p[1:-1, 2:]
    full = c + sdc * (
        pw + (n + s - 2.0 * c) * ry1 + (e + w_ - 2.0 * c) * rx1 + (amb - c) * rz1
    )
    o_ref[...] = lax.dynamic_slice(full, (i * ROW_CHUNK, 0), (ROW_CHUNK, w))


def hotspot2d_step(temp, power, coeffs, *, interpret=True):
    """Single Hotspot-2D time-step over (H, W) tiles; H % ROW_CHUNK == 0."""
    h, w = temp.shape
    assert h % ROW_CHUNK == 0, f"tile height {h} not a multiple of {ROW_CHUNK}"
    return pl.pallas_call(
        _hotspot2d_kernel,
        grid=(h // ROW_CHUNK,),
        in_specs=[
            pl.BlockSpec((h, w), lambda i: (0, 0)),
            pl.BlockSpec((h, w), lambda i: (0, 0)),
            pl.BlockSpec((5,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((ROW_CHUNK, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), temp.dtype),
        interpret=interpret,
    )(temp, power, coeffs)


def _hotspot3d_kernel(t_ref, pw_ref, c_ref, o_ref):
    """Full-tile Hotspot-3D update.

    t_ref/pw_ref: (D, H, W) tiles, c_ref: (9,)
    [cc, cn, cs, cw, ce, ca, cb, sdc, amb].
    out = c*cc + n*cn + s*cs + e*ce + w*cw + a*ca + b*cb + sdc*power + ca*amb
    """
    t = t_ref[...]
    pw = pw_ref[...]
    p = jnp.pad(t, ((1, 1), (1, 1), (1, 1)), mode="edge")
    cc, cn, cs, cw, ce, ca, cb, sdc, amb = (c_ref[k] for k in range(9))
    o_ref[...] = (
        p[1:-1, 1:-1, 1:-1] * cc
        + p[1:-1, :-2, 1:-1] * cn
        + p[1:-1, 2:, 1:-1] * cs
        + p[1:-1, 1:-1, 2:] * ce
        + p[1:-1, 1:-1, :-2] * cw
        + p[:-2, 1:-1, 1:-1] * ca
        + p[2:, 1:-1, 1:-1] * cb
        + sdc * pw
        + ca * amb
    )


def hotspot3d_step(temp, power, coeffs, *, interpret=True):
    """Single Hotspot-3D time-step over (D, H, W) tiles."""
    return pl.pallas_call(
        _hotspot3d_kernel,
        out_shape=jax.ShapeDtypeStruct(temp.shape, temp.dtype),
        interpret=interpret,
    )(temp, power, coeffs)
