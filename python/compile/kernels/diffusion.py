"""L1 Pallas kernels: Diffusion 2D / 3D single-step tile update.

Hardware adaptation (DESIGN.md §3): the paper's shift register streams rows
of the spatial block through FPGA Block RAM with static-offset neighbor taps.
On the TPU-shaped Pallas model the spatial block is a VMEM-resident tile:

* 2D: the kernel is *row-streamed* — `pallas_call` runs a 1-D grid over row
  chunks of the tile; the whole tile is the input block (the "shift
  register" contents) and each program emits one row-chunk of the output
  (the cells leaving the pipeline that cycle). Neighbor taps are static
  offsets into the tile, exactly like the FPGA design's static shift
  register addressing.
* 3D: the tile (planes × rows × cols) is one VMEM block and the kernel
  computes the full tile in a single program (plane streaming is handled by
  the L3 coordinator's z-traversal, as in the paper's 3D z-streaming).

Boundary rule inside a tile: edge clamp. The coordinator always supplies
`halo = rad × par_time` cells of real data around the compute block, so the
clamped ring never propagates into cells that are written back (the Fig 5
shrinking-compute-block argument).

Kernels must be lowered with interpret=True — real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

# Rows of the output tile emitted per grid step of the 2-D streamed kernel.
ROW_CHUNK = 8


def _diffusion2d_kernel(x_ref, c_ref, o_ref):
    """One grid-step: compute ROW_CHUNK rows of the diffusion-2D update.

    x_ref: (H, W) full tile (the shift-register contents)
    c_ref: (5,) coefficients [cc, cn, cs, cw, ce]
    o_ref: (ROW_CHUNK, W) output row chunk
    """
    i = pl.program_id(0)
    x = x_ref[...]
    h, w = x.shape
    p = jnp.pad(x, ((1, 1), (1, 1)), mode="edge")
    cc, cn, cs, cw, ce = (c_ref[k] for k in range(5))
    full = (
        cc * p[1:-1, 1:-1]
        + cw * p[1:-1, :-2]
        + ce * p[1:-1, 2:]
        + cs * p[2:, 1:-1]
        + cn * p[:-2, 1:-1]
    )
    o_ref[...] = lax.dynamic_slice(full, (i * ROW_CHUNK, 0), (ROW_CHUNK, w))


def diffusion2d_step(x, coeffs, *, interpret=True):
    """Single diffusion-2D time-step over a (H, W) tile; H % ROW_CHUNK == 0."""
    h, w = x.shape
    assert h % ROW_CHUNK == 0, f"tile height {h} not a multiple of {ROW_CHUNK}"
    return pl.pallas_call(
        _diffusion2d_kernel,
        grid=(h // ROW_CHUNK,),
        in_specs=[
            pl.BlockSpec((h, w), lambda i: (0, 0)),
            pl.BlockSpec((5,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((ROW_CHUNK, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), x.dtype),
        interpret=interpret,
    )(x, coeffs)


def _diffusion2d_r2_kernel(x_ref, c_ref, o_ref):
    """One grid-step of the radius-2 (9-point star) diffusion update —
    the paper's §8 high-order-stencil extension.

    x_ref: (H, W) tile, c_ref: (9,) [cc, cn1, cs1, cw1, ce1, cn2, cs2,
    cw2, ce2], o_ref: (ROW_CHUNK, W).
    """
    i = pl.program_id(0)
    x = x_ref[...]
    h, w = x.shape
    p = jnp.pad(x, ((2, 2), (2, 2)), mode="edge")
    cc, cn1, cs1, cw1, ce1, cn2, cs2, cw2, ce2 = (c_ref[k] for k in range(9))
    full = (
        cc * p[2:-2, 2:-2]
        + cn1 * p[1:-3, 2:-2]
        + cs1 * p[3:-1, 2:-2]
        + cw1 * p[2:-2, 1:-3]
        + ce1 * p[2:-2, 3:-1]
        + cn2 * p[:-4, 2:-2]
        + cs2 * p[4:, 2:-2]
        + cw2 * p[2:-2, :-4]
        + ce2 * p[2:-2, 4:]
    )
    o_ref[...] = lax.dynamic_slice(full, (i * ROW_CHUNK, 0), (ROW_CHUNK, w))


def diffusion2d_r2_step(x, coeffs, *, interpret=True):
    """Single radius-2 diffusion time-step over a (H, W) tile."""
    h, w = x.shape
    assert h % ROW_CHUNK == 0, f"tile height {h} not a multiple of {ROW_CHUNK}"
    return pl.pallas_call(
        _diffusion2d_r2_kernel,
        grid=(h // ROW_CHUNK,),
        in_specs=[
            pl.BlockSpec((h, w), lambda i: (0, 0)),
            pl.BlockSpec((9,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((ROW_CHUNK, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), x.dtype),
        interpret=interpret,
    )(x, coeffs)


def _diffusion3d_kernel(x_ref, c_ref, o_ref):
    """Full-tile diffusion-3D update; tile is one VMEM block.

    x_ref: (D, H, W) tile, c_ref: (7,) [cc, cn, cs, cw, ce, ca, cb].
    Axis 0 = z (above = z-1, below = z+1), axis 1 = y, axis 2 = x.
    """
    x = x_ref[...]
    p = jnp.pad(x, ((1, 1), (1, 1), (1, 1)), mode="edge")
    cc, cn, cs, cw, ce, ca, cb = (c_ref[k] for k in range(7))
    o_ref[...] = (
        cc * p[1:-1, 1:-1, 1:-1]
        + cw * p[1:-1, 1:-1, :-2]
        + ce * p[1:-1, 1:-1, 2:]
        + cs * p[1:-1, 2:, 1:-1]
        + cn * p[1:-1, :-2, 1:-1]
        + cb * p[2:, 1:-1, 1:-1]
        + ca * p[:-2, 1:-1, 1:-1]
    )


def diffusion3d_step(x, coeffs, *, interpret=True):
    """Single diffusion-3D time-step over a (D, H, W) tile."""
    return pl.pallas_call(
        _diffusion3d_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, coeffs)
