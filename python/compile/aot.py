"""AOT: lower every (stencil, tile, steps) tile-program variant to HLO text.

HLO *text* (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs:
  artifacts/<name>.hlo.txt      one per variant
  artifacts/manifest.json       what Rust loads: shapes, arg order, steps

Python runs ONCE at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from .model import STENCILS, abstract_args, build_fn

#: The artifact set Rust's runtime may request. Tile shapes are powers of
#: two (§5.3 restriction: efficient mod-indexed block traversal); `steps`
#: is the paper's par_time folded into the tile program. The coordinator
#: maps its (bsize, par_time) plan onto the closest variant.
VARIANTS = [
    # (kind, tile_shape, steps)
    ("diffusion2d", (64, 64), 1),
    ("diffusion2d", (64, 64), 2),
    ("diffusion2d", (64, 64), 4),
    ("diffusion2d", (64, 64), 8),
    ("diffusion2d", (128, 128), 4),
    # §Perf L1: larger VMEM tiles amortize per-dispatch overhead (a 256²
    # f32 tile is 256 KiB — far below the ~16 MiB VMEM budget even with
    # double buffering).
    ("diffusion2d", (256, 256), 8),
    ("hotspot2d", (64, 64), 1),
    ("hotspot2d", (64, 64), 2),
    ("hotspot2d", (64, 64), 4),
    ("diffusion3d", (16, 16, 16), 1),
    ("diffusion3d", (16, 16, 16), 2),
    ("diffusion3d", (32, 32, 32), 4),
    ("hotspot3d", (16, 16, 16), 1),
    ("hotspot3d", (16, 16, 16), 2),
    # §8 high-order extension: radius-2 needs halo = 2*steps per side.
    ("diffusion2dr2", (64, 64), 1),
    ("diffusion2dr2", (64, 64), 2),
    ("diffusion2dr2", (64, 64), 4),
]


def variant_name(kind, tile_shape, steps):
    dims = "x".join(str(d) for d in tile_shape)
    return f"{kind}_t{dims}_s{steps}"


def to_hlo_text(lowered):
    """stablehlo MLIR -> XlaComputation -> HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(kind, tile_shape, steps):
    fn = build_fn(kind, steps, interpret=True)
    args = abstract_args(kind, tile_shape)
    return to_hlo_text(jax.jit(fn).lower(*args))


def build_all(out_dir, variants=VARIANTS, verbose=True):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": 1, "variants": []}
    for kind, tile_shape, steps in variants:
        name = variant_name(kind, tile_shape, steps)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        text = lower_variant(kind, tile_shape, steps)
        with open(path, "w") as f:
            f.write(text)
        coeff_len, has_power, _ = STENCILS[kind]
        manifest["variants"].append(
            {
                "name": name,
                "kind": kind,
                "tile": list(tile_shape),
                "steps": steps,
                "has_power": has_power,
                "coeff_len": coeff_len,
                "file": f"{name}.hlo.txt",
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            }
        )
        if verbose:
            print(f"  {name}: {len(text)} chars", file=sys.stderr)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="stamp path; artifacts land in its directory")
    args = ap.parse_args()
    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    manifest = build_all(out_dir)
    # The Makefile stamp target: write the first variant's HLO there too so
    # `make -q artifacts` sees a fresh file.
    with open(args.out, "w") as f:
        first = manifest["variants"][0]["file"]
        with open(os.path.join(out_dir, first)) as g:
            f.write(g.read())
    print(f"wrote {len(manifest['variants'])} artifacts to {out_dir}")


if __name__ == "__main__":
    main()
