"""L2: the JAX compute graph — `par_time` fused stencil time-steps per tile.

The paper's temporal blocking chains `par_time` replicated PEs over on-chip
channels so one external-memory round-trip covers `par_time` time-steps
(§3.2). Here the same arithmetic-intensity amplification is a
`lax.fori_loop` of the L1 Pallas step over a VMEM-resident tile: one
HBM→VMEM→HBM round-trip per `par_time` steps.

Each (stencil, tile-shape, steps) variant is lowered once by aot.py to HLO
text and executed from Rust; the tile result's outer `rad × steps` ring is
garbage-by-clamping and is discarded by the coordinator (the Fig 5
shrinking compute block).

Coefficients are a runtime argument array — like the paper, changing them
does not require recompiling the kernel (§5.1).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import (
    diffusion2d_r2_step,
    diffusion2d_step,
    diffusion3d_step,
    hotspot2d_step,
    hotspot3d_step,
)

#: kind -> (coefficient vector length, needs power-grid input, ndim)
STENCILS = {
    "diffusion2d": (5, False, 2),
    "diffusion3d": (7, False, 3),
    "hotspot2d": (5, True, 2),
    "hotspot3d": (9, True, 3),
    # §8 high-order extension: radius-2 star diffusion.
    "diffusion2dr2": (9, False, 2),
}


def multi_step_diffusion2d(x, coeffs, *, steps, interpret=True):
    """`steps` fused Diffusion-2D time-steps over a (H, W) tile."""
    body = lambda _, v: diffusion2d_step(v, coeffs, interpret=interpret)
    return (lax.fori_loop(0, steps, body, x),)


def multi_step_diffusion3d(x, coeffs, *, steps, interpret=True):
    """`steps` fused Diffusion-3D time-steps over a (D, H, W) tile."""
    body = lambda _, v: diffusion3d_step(v, coeffs, interpret=interpret)
    return (lax.fori_loop(0, steps, body, x),)


def multi_step_diffusion2dr2(x, coeffs, *, steps, interpret=True):
    """`steps` fused radius-2 diffusion time-steps over a (H, W) tile."""
    body = lambda _, v: diffusion2d_r2_step(v, coeffs, interpret=interpret)
    return (lax.fori_loop(0, steps, body, x),)


def multi_step_hotspot2d(x, power, coeffs, *, steps, interpret=True):
    """`steps` fused Hotspot-2D time-steps; `power` is constant across steps."""
    body = lambda _, v: hotspot2d_step(v, power, coeffs, interpret=interpret)
    return (lax.fori_loop(0, steps, body, x),)


def multi_step_hotspot3d(x, power, coeffs, *, steps, interpret=True):
    """`steps` fused Hotspot-3D time-steps; `power` is constant across steps."""
    body = lambda _, v: hotspot3d_step(v, power, coeffs, interpret=interpret)
    return (lax.fori_loop(0, steps, body, x),)


_MULTI = {
    "diffusion2d": multi_step_diffusion2d,
    "diffusion3d": multi_step_diffusion3d,
    "hotspot2d": multi_step_hotspot2d,
    "hotspot3d": multi_step_hotspot3d,
    "diffusion2dr2": multi_step_diffusion2dr2,
}


def build_fn(kind, steps, interpret=True):
    """Return the jit-able tile function for `kind` with `steps` fused steps.

    Signature: (x[, power], coeffs) -> (out,)  — a 1-tuple, matching the
    `return_tuple=True` lowering convention the Rust loader unwraps with
    `to_tuple1()`.
    """
    if kind not in _MULTI:
        raise ValueError(f"unknown stencil kind: {kind}")
    return partial(_MULTI[kind], steps=steps, interpret=interpret)


def abstract_args(kind, tile_shape):
    """ShapeDtypeStructs for `build_fn(kind, ...)` at `tile_shape` (f32)."""
    coeff_len, has_power, ndim = STENCILS[kind]
    if len(tile_shape) != ndim:
        raise ValueError(f"{kind} expects {ndim}-D tiles, got {tile_shape}")
    tile = jax.ShapeDtypeStruct(tuple(tile_shape), jnp.float32)
    coeffs = jax.ShapeDtypeStruct((coeff_len,), jnp.float32)
    return (tile, tile, coeffs) if has_power else (tile, coeffs)
