"""pytest: the radius-2 (high-order, §8 extension) stencil — kernel vs
oracle, and the rad=2 halo-validity invariant (halo = 2·steps)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ROW_CHUNK, diffusion2d_r2_step, ref
from compile.model import build_fn

RTOL, ATOL = 1e-5, 1e-5

C = jnp.asarray(np.float32([0.4, 0.12, 0.12, 0.12, 0.12, 0.03, 0.03, 0.03, 0.03]))


def rand(shape, seed):
    return jnp.asarray(np.random.RandomState(seed).rand(*shape).astype(np.float32))


def test_matches_ref():
    x = rand((32, 32), 0)
    np.testing.assert_allclose(
        diffusion2d_r2_step(x, C), ref.diffusion2d_r2(x, *C), rtol=RTOL, atol=ATOL
    )


def test_constant_fixed_point():
    x = jnp.full((16, 16), 5.0, jnp.float32)
    np.testing.assert_allclose(diffusion2d_r2_step(x, C), x, rtol=RTOL, atol=ATOL)


def test_far_tap_shifts_by_two():
    x = rand((24, 8), 3)
    c = jnp.zeros(9, jnp.float32).at[5].set(1.0)  # pure cn2
    out = np.asarray(diffusion2d_r2_step(x, c))
    xs = np.asarray(x)
    np.testing.assert_allclose(out[2:], xs[:-2], rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(out[0], xs[0], rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(out[1], xs[0], rtol=RTOL, atol=ATOL)  # clamp(-1)=0


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(1, 5).map(lambda k: k * ROW_CHUNK),
    w=st.integers(5, 40),
    seed=st.integers(0, 2**16),
)
def test_shapes(h, w, seed):
    x = rand((h, w), seed)
    c = jnp.asarray(np.random.RandomState(seed + 1).rand(9).astype(np.float32))
    np.testing.assert_allclose(
        diffusion2d_r2_step(x, c), ref.diffusion2d_r2(x, *c), rtol=RTOL, atol=ATOL
    )


@pytest.mark.parametrize("steps", [1, 2, 4])
def test_halo_validity_rad2(steps):
    """Interior at distance >= 2*steps from the tile edge is exact —
    the Eq 2 halo for radius 2."""
    halo = 2 * steps
    grid = rand((96, 96), 7)
    want = ref.multi_step_ref("diffusion2dr2", steps, grid, coeffs=tuple(C))
    y0, x0, th, tw = 16, 24, 40, 48
    tile = grid[y0 : y0 + th, x0 : x0 + tw]
    got = build_fn("diffusion2dr2", steps)(tile, C)[0]
    np.testing.assert_allclose(
        np.asarray(got)[halo : th - halo, halo : tw - halo],
        np.asarray(want)[y0 + halo : y0 + th - halo, x0 + halo : x0 + tw - halo],
        rtol=RTOL,
        atol=1e-4,
    )


def test_halo_distance_one_short_is_not_enough():
    """Negative control: at distance 2*steps - 1 the clamp contamination
    IS visible — confirming the Eq 2 halo is tight for rad = 2."""
    steps, halo = 2, 4
    grid = rand((96, 96), 8)
    want = ref.multi_step_ref("diffusion2dr2", steps, grid, coeffs=tuple(C))
    y0, x0, th, tw = 16, 24, 40, 48
    tile = grid[y0 : y0 + th, x0 : x0 + tw]
    got = build_fn("diffusion2dr2", steps)(tile, C)[0]
    ring = halo - 1
    diff = np.abs(
        np.asarray(got)[ring : th - ring, ring : tw - ring]
        - np.asarray(want)[y0 + ring : y0 + th - ring, x0 + ring : x0 + tw - ring]
    )
    assert diff.max() > 1e-6, "halo should be tight; ring-1 must differ"
