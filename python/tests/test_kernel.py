"""pytest: L1 Pallas kernels vs the pure-jnp oracle — the CORE correctness
signal of the build path.

Hypothesis sweeps shapes and values; fixed-seed cases pin the four Table 2
formulas and the clamp boundary rule.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    ROW_CHUNK,
    diffusion2d_step,
    diffusion3d_step,
    hotspot2d_step,
    hotspot3d_step,
    ref,
)

RTOL, ATOL = 1e-5, 1e-5


def rand(shape, seed):
    return jnp.asarray(np.random.RandomState(seed).rand(*shape).astype(np.float32))


def diff_coeffs(n):
    # Convex-ish weights: keeps iterated application numerically tame.
    return jnp.asarray(np.float32([1.0 / n] * n))


HS2D = jnp.asarray(np.float32([0.05, 0.3, 0.2, 0.1, 80.0]))  # sdc rx1 ry1 rz1 amb
HS3D = jnp.asarray(
    np.float32([0.4, 0.1, 0.1, 0.1, 0.1, 0.1, 0.1, 0.01, 80.0])
)  # cc cn cs cw ce ca cb sdc amb


# ---------------------------------------------------------------- fixed cases
class TestDiffusion2D:
    def test_matches_ref(self):
        x = rand((32, 32), 0)
        c = diff_coeffs(5)
        np.testing.assert_allclose(
            diffusion2d_step(x, c), ref.diffusion2d(x, *c), rtol=RTOL, atol=ATOL
        )

    def test_constant_field_fixed_point(self):
        """With sum(coeffs)=1, a constant field is a fixed point."""
        x = jnp.full((16, 16), 3.5, jnp.float32)
        out = diffusion2d_step(x, diff_coeffs(5))
        np.testing.assert_allclose(out, x, rtol=RTOL, atol=ATOL)

    def test_boundary_clamp(self):
        """Out-of-bound neighbors fall back on the boundary cell (§5.1)."""
        x = rand((16, 16), 3)
        c = jnp.asarray(np.float32([0.0, 1.0, 0.0, 0.0, 0.0]))  # pure north tap
        out = np.asarray(diffusion2d_step(x, c))
        # row 0's north neighbor is row 0 itself
        np.testing.assert_allclose(out[0], np.asarray(x)[0], rtol=RTOL, atol=ATOL)
        np.testing.assert_allclose(out[1:], np.asarray(x)[:-1], rtol=RTOL, atol=ATOL)

    def test_asymmetric_coeffs(self):
        x = rand((24, 40), 4)
        c = jnp.asarray(np.float32([0.5, 0.1, 0.2, 0.15, 0.05]))
        np.testing.assert_allclose(
            diffusion2d_step(x, c), ref.diffusion2d(x, *c), rtol=RTOL, atol=ATOL
        )


class TestDiffusion3D:
    def test_matches_ref(self):
        x = rand((8, 16, 16), 1)
        c = diff_coeffs(7)
        np.testing.assert_allclose(
            diffusion3d_step(x, c), ref.diffusion3d(x, *c), rtol=RTOL, atol=ATOL
        )

    def test_constant_field_fixed_point(self):
        x = jnp.full((8, 8, 8), -2.25, jnp.float32)
        out = diffusion3d_step(x, diff_coeffs(7))
        np.testing.assert_allclose(out, x, rtol=RTOL, atol=ATOL)

    def test_axis_convention(self):
        """Above = z-1 (axis 0). A pure `ca` tap shifts planes down."""
        x = rand((6, 8, 8), 5)
        c = jnp.asarray(np.float32([0, 0, 0, 0, 0, 1.0, 0]))  # ca only
        out = np.asarray(diffusion3d_step(x, c))
        np.testing.assert_allclose(out[0], np.asarray(x)[0], rtol=RTOL, atol=ATOL)
        np.testing.assert_allclose(out[1:], np.asarray(x)[:-1], rtol=RTOL, atol=ATOL)


class TestHotspot2D:
    def test_matches_ref(self):
        t, p = rand((32, 32), 2), rand((32, 32), 20)
        np.testing.assert_allclose(
            hotspot2d_step(t, p, HS2D),
            ref.hotspot2d(t, p, *HS2D),
            rtol=RTOL,
            atol=ATOL,
        )

    def test_equilibrium(self):
        """temp == amb everywhere, zero power => temp unchanged."""
        t = jnp.full((16, 16), float(HS2D[4]), jnp.float32)
        p = jnp.zeros((16, 16), jnp.float32)
        out = hotspot2d_step(t, p, HS2D)
        np.testing.assert_allclose(out, t, rtol=RTOL, atol=1e-4)

    def test_power_injects_heat(self):
        t = jnp.full((16, 16), float(HS2D[4]), jnp.float32)
        p = jnp.zeros((16, 16), jnp.float32).at[8, 8].set(10.0)
        out = np.asarray(hotspot2d_step(t, p, HS2D))
        assert out[8, 8] > float(HS2D[4])
        assert np.all(out >= float(HS2D[4]) - 1e-4)


class TestHotspot3D:
    def test_matches_ref(self):
        t, p = rand((8, 16, 16), 6), rand((8, 16, 16), 60)
        np.testing.assert_allclose(
            hotspot3d_step(t, p, HS3D),
            ref.hotspot3d(t, p, *HS3D),
            rtol=RTOL,
            atol=ATOL,
        )

    def test_matches_ref_noncubic(self):
        t, p = rand((4, 8, 24), 7), rand((4, 8, 24), 70)
        np.testing.assert_allclose(
            hotspot3d_step(t, p, HS3D),
            ref.hotspot3d(t, p, *HS3D),
            rtol=RTOL,
            atol=ATOL,
        )


# ------------------------------------------------------------- hypothesis
@settings(max_examples=20, deadline=None)
@given(
    h=st.integers(1, 6).map(lambda k: k * ROW_CHUNK),
    w=st.integers(4, 48),
    seed=st.integers(0, 2**16),
)
def test_diffusion2d_shapes(h, w, seed):
    x = rand((h, w), seed)
    c = jnp.asarray(np.random.RandomState(seed + 1).rand(5).astype(np.float32))
    np.testing.assert_allclose(
        diffusion2d_step(x, c), ref.diffusion2d(x, *c), rtol=RTOL, atol=ATOL
    )


@settings(max_examples=15, deadline=None)
@given(
    d=st.integers(2, 10),
    h=st.integers(2, 12),
    w=st.integers(2, 12),
    seed=st.integers(0, 2**16),
)
def test_diffusion3d_shapes(d, h, w, seed):
    x = rand((d, h, w), seed)
    c = jnp.asarray(np.random.RandomState(seed + 1).rand(7).astype(np.float32))
    np.testing.assert_allclose(
        diffusion3d_step(x, c), ref.diffusion3d(x, *c), rtol=RTOL, atol=ATOL
    )


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(1, 4).map(lambda k: k * ROW_CHUNK),
    w=st.integers(4, 32),
    seed=st.integers(0, 2**16),
)
def test_hotspot2d_shapes(h, w, seed):
    t, p = rand((h, w), seed), rand((h, w), seed + 9)
    np.testing.assert_allclose(
        hotspot2d_step(t, p, HS2D), ref.hotspot2d(t, p, *HS2D), rtol=RTOL, atol=ATOL
    )


@settings(max_examples=15, deadline=None)
@given(
    d=st.integers(2, 8),
    h=st.integers(2, 10),
    w=st.integers(2, 10),
    seed=st.integers(0, 2**16),
)
def test_hotspot3d_shapes(d, h, w, seed):
    t, p = rand((d, h, w), seed), rand((d, h, w), seed + 9)
    np.testing.assert_allclose(
        hotspot3d_step(t, p, HS3D), ref.hotspot3d(t, p, *HS3D), rtol=RTOL, atol=ATOL
    )
