"""pytest: L2 multi-step tile programs — fused-step semantics and the
halo-validity invariant that the Rust coordinator relies on.

The invariant (DESIGN.md §3, paper Fig 5): run T fused steps on a tile cut
from a larger grid with `halo = rad*T` cells of real data around the compute
block; then the tile interior at distance >= rad*T from the tile edge must
equal the whole-grid reference, bit-for-tolerance — i.e. the tile-edge clamp
never contaminates cells the coordinator writes back.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ROW_CHUNK, ref
from compile.model import STENCILS, abstract_args, build_fn

RTOL, ATOL = 1e-5, 1e-5


def rand(shape, seed):
    return jnp.asarray(np.random.RandomState(seed).rand(*shape).astype(np.float32))


COEFFS = {
    "diffusion2d": jnp.asarray(np.float32([0.2] * 5)),
    "diffusion3d": jnp.asarray(np.float32([1 / 7] * 7)),
    "hotspot2d": jnp.asarray(np.float32([0.05, 0.3, 0.2, 0.1, 80.0])),
    "hotspot3d": jnp.asarray(
        np.float32([0.4, 0.1, 0.1, 0.1, 0.1, 0.1, 0.1, 0.01, 80.0])
    ),
    "diffusion2dr2": jnp.asarray(
        np.float32([0.4, 0.12, 0.12, 0.12, 0.12, 0.03, 0.03, 0.03, 0.03])
    ),
}


def run_variant(kind, tile, steps, x, power=None):
    fn = build_fn(kind, steps)
    if STENCILS[kind][1]:
        return fn(x, power, COEFFS[kind])[0]
    return fn(x, COEFFS[kind])[0]


# ------------------------------------------------ fused steps == iterated ref
@pytest.mark.parametrize("steps", [1, 2, 4])
@pytest.mark.parametrize("kind", list(STENCILS))
def test_multi_step_matches_iterated_ref(kind, steps):
    _, has_power, ndim = STENCILS[kind]
    shape = (32, 32) if ndim == 2 else (8, 12, 12)
    x = rand(shape, hash((kind, steps)) % 1000)
    p = rand(shape, 999) if has_power else None
    got = run_variant(kind, shape, steps, x, p)
    want = ref.multi_step_ref(kind, steps, x, power=p, coeffs=tuple(COEFFS[kind]))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=10 * ATOL)


# ------------------------------------------------------- halo validity (2D)
@pytest.mark.parametrize("kind", ["diffusion2d", "hotspot2d"])
@pytest.mark.parametrize("steps", [1, 2, 4])
def test_halo_validity_2d(kind, steps):
    rad = 1
    halo = rad * steps
    grid = rand((96, 96), 11)
    pgrid = rand((96, 96), 12)
    _, has_power, _ = STENCILS[kind]
    # whole-grid reference after `steps` iterations
    want = ref.multi_step_ref(
        kind, steps, grid, power=pgrid if has_power else None,
        coeffs=tuple(COEFFS[kind]),
    )
    # tile cut from the interior (so clamp semantics inside the tile are the
    # only difference from the true neighborhood)
    y0, x0, th, tw = 16, 24, 32, 48
    tile = grid[y0 : y0 + th, x0 : x0 + tw]
    ptile = pgrid[y0 : y0 + th, x0 : x0 + tw] if has_power else None
    got = run_variant(kind, (th, tw), steps, tile, ptile)
    np.testing.assert_allclose(
        np.asarray(got)[halo : th - halo, halo : tw - halo],
        np.asarray(want)[y0 + halo : y0 + th - halo, x0 + halo : x0 + tw - halo],
        rtol=RTOL,
        atol=10 * ATOL,
    )


# ------------------------------------------------------- halo validity (3D)
@pytest.mark.parametrize("kind", ["diffusion3d", "hotspot3d"])
@pytest.mark.parametrize("steps", [1, 2])
def test_halo_validity_3d(kind, steps):
    rad = 1
    halo = rad * steps
    grid = rand((24, 24, 24), 21)
    pgrid = rand((24, 24, 24), 22)
    _, has_power, _ = STENCILS[kind]
    want = ref.multi_step_ref(
        kind, steps, grid, power=pgrid if has_power else None,
        coeffs=tuple(COEFFS[kind]),
    )
    z0, y0, x0, td, th, tw = 4, 6, 8, 12, 12, 16
    tile = grid[z0 : z0 + td, y0 : y0 + th, x0 : x0 + tw]
    ptile = pgrid[z0 : z0 + td, y0 : y0 + th, x0 : x0 + tw] if has_power else None
    got = run_variant(kind, (td, th, tw), steps, tile, ptile)
    np.testing.assert_allclose(
        np.asarray(got)[halo : td - halo, halo : th - halo, halo : tw - halo],
        np.asarray(want)[
            z0 + halo : z0 + td - halo,
            y0 + halo : y0 + th - halo,
            x0 + halo : x0 + tw - halo,
        ],
        rtol=RTOL,
        atol=10 * ATOL,
    )


# ------------------------------------------------ grid-edge tiles also valid
def test_halo_validity_grid_corner_2d():
    """A tile flush with the grid corner: the clamped tile edge coincides
    with the clamped grid edge, so even the halo ring is exact there."""
    steps, halo = 2, 2
    grid = rand((64, 64), 31)
    want = ref.multi_step_ref(
        "diffusion2d", steps, grid, coeffs=tuple(COEFFS["diffusion2d"])
    )
    tile = grid[0:32, 0:32]
    got = run_variant("diffusion2d", (32, 32), steps, tile)
    # valid region: everything at least `halo` away from the two tile edges
    # that are NOT grid edges (right, bottom)
    np.testing.assert_allclose(
        np.asarray(got)[: 32 - halo, : 32 - halo],
        np.asarray(want)[: 32 - halo, : 32 - halo],
        rtol=RTOL,
        atol=1e-4,
    )


def test_abstract_args_shapes():
    args = abstract_args("hotspot2d", (64, 64))
    assert len(args) == 3
    assert args[0].shape == (64, 64) and args[2].shape == (5,)
    args = abstract_args("diffusion3d", (16, 16, 16))
    assert len(args) == 2 and args[1].shape == (7,)
    with pytest.raises(ValueError):
        abstract_args("diffusion2d", (16, 16, 16))


@settings(max_examples=8, deadline=None)
@given(steps=st.integers(1, 4), seed=st.integers(0, 2**16))
def test_fused_vs_two_chunks_2d(steps, seed):
    """T fused steps twice == 2T iterated reference steps (the coordinator's
    iteration chunking: ceil(iter/par_time) passes)."""
    x = rand((40, 40), seed)
    c = COEFFS["diffusion2d"]
    once = run_variant("diffusion2d", (40, 40), steps, x)
    twice = run_variant("diffusion2d", (40, 40), steps, once)
    want = ref.multi_step_ref("diffusion2d", 2 * steps, x, coeffs=tuple(c))
    # only the interior at distance 2*steps is exact (tile == whole grid here,
    # so everything matches — clamp IS the grid boundary rule)
    np.testing.assert_allclose(twice, want, rtol=RTOL, atol=1e-4)
