//! Quickstart: run a Diffusion 2D problem through the engine API and
//! verify the blocked execution against the scalar oracle.
//!
//!     cargo run --release --example quickstart
//!
//! The front door is `StencilEngine`: pick a typed `Backend`, build a
//! `Plan`, open a warm `Session`, submit grids. (For the AOT/PJRT
//! artifact path see `examples/heat_sim.rs` or `fstencil run --backend
//! pjrt`.)

use fstencil::coordinator::PlanBuilder;
use fstencil::engine::{Backend, StencilEngine};
use fstencil::stencil::{reference, Grid, StencilKind};

fn main() -> anyhow::Result<()> {
    let kind = StencilKind::Diffusion2D;
    let (h, w, iters) = (256, 256, 24);

    // A Gaussian heat bump in the middle of the grid.
    let mut grid = Grid::new2d(h, w);
    grid.fill_gaussian(0.0, 1.0, 0.08);
    let initial_mass = grid.sum();

    let backend = Backend::Vec { par_vec: 8 };
    let plan = PlanBuilder::new(kind)
        .grid_dims(vec![h, w])
        .iterations(iters)
        .backend(backend)
        .build()?;
    println!(
        "plan: backend {backend}, tile {:?}, chunk schedule {:?} ({} passes)",
        plan.tile,
        plan.chunks,
        plan.passes()
    );

    // A session owns warm worker threads + tile pools; this example
    // submits once, but every further submit would reuse them.
    let mut session = StencilEngine::new().session(plan.clone())?;
    let before = grid.clone();
    let out = session.submit(grid).wait()?;
    let report = &out.report;
    println!(
        "ran {} tiles on {} in {:.1} ms -> {:.1} Mcell/s useful, redundancy {:.3}",
        report.tiles_executed,
        report.backend,
        report.elapsed.as_secs_f64() * 1e3,
        report.mcells_per_sec(),
        report.redundancy()
    );

    // Check against the whole-grid scalar oracle.
    let want = reference::run(kind, &before, None, &plan.coeffs, iters);
    let err = out.grid.max_abs_diff(&want);
    println!("max |err| vs oracle = {err:.3e}");
    anyhow::ensure!(err < 1e-3, "verification failed");

    // Physics sanity: diffusion conserves mass away from boundaries.
    let final_mass = out.grid.sum();
    println!("mass {initial_mass:.4} -> {final_mass:.4} (diffusion conserves)");
    println!("quickstart OK");
    Ok(())
}
