//! Quickstart: run a Diffusion 2D problem through the public API and
//! verify the blocked execution against the scalar oracle.
//!
//!     cargo run --release --example quickstart
//!
//! Uses the PJRT backend when `make artifacts` has been run, otherwise
//! falls back to the in-process host executor.

use fstencil::coordinator::{Coordinator, PlanBuilder};
use fstencil::runtime::{Executor, HostExecutor, PjrtExecutor};
use fstencil::stencil::{reference, Grid, StencilKind};

fn main() -> anyhow::Result<()> {
    let kind = StencilKind::Diffusion2D;
    let (h, w, iters) = (256, 256, 24);

    // A Gaussian heat bump in the middle of the grid.
    let mut grid = Grid::new2d(h, w);
    grid.fill_gaussian(0.0, 1.0, 0.08);
    let initial_mass = grid.sum();

    // Prefer the AOT/PJRT path (python never runs here — artifacts were
    // lowered once by `make artifacts`).
    let exec: Box<dyn Executor> = match PjrtExecutor::load_default() {
        Ok(p) => {
            println!("backend: PJRT ({})", p.platform());
            Box::new(p)
        }
        Err(e) => {
            println!("backend: host fallback ({e})");
            Box::new(HostExecutor::new())
        }
    };

    let plan = PlanBuilder::new(kind)
        .grid_dims(vec![h, w])
        .iterations(iters)
        .for_executor(exec.as_ref())
        .build()?;
    println!(
        "plan: tile {:?}, chunk schedule {:?} ({} passes)",
        plan.tile,
        plan.chunks,
        plan.passes()
    );

    let before = grid.clone();
    let report = Coordinator::new(plan.clone()).run(exec.as_ref(), &mut grid, None)?;
    println!(
        "ran {} tiles in {:.1} ms -> {:.1} Mcell/s useful, redundancy {:.3}",
        report.tiles_executed,
        report.elapsed.as_secs_f64() * 1e3,
        report.mcells_per_sec(),
        report.redundancy()
    );

    // Check against the whole-grid scalar oracle.
    let want = reference::run(kind, &before, None, &plan.coeffs, iters);
    let err = grid.max_abs_diff(&want);
    println!("max |err| vs oracle = {err:.3e}");
    anyhow::ensure!(err < 1e-3, "verification failed");

    // Physics sanity: diffusion conserves mass away from boundaries.
    let final_mass = grid.sum();
    println!("mass {initial_mass:.4} -> {final_mass:.4} (diffusion conserves)");
    println!("quickstart OK");
    Ok(())
}
