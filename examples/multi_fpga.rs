//! Multi-device spatial distribution (the paper's §8 future work): a
//! large Diffusion 2D grid split into slabs across N shard workers with
//! per-pass halo exchange over real loopback TCP. Demonstrates
//! correctness (vs the oracle) and the communication/computation scaling
//! that makes distribution viable.
//!
//!     cargo run --release --example multi_fpga

use fstencil::cluster::{ClusterCoordinator, WorkerLauncher};
use fstencil::coordinator::PlanBuilder;
use fstencil::engine::Backend;
use fstencil::stencil::{reference, Grid, StencilKind};

fn main() -> anyhow::Result<()> {
    let kind = StencilKind::Diffusion2D;
    let (h, w, iters) = (1024usize, 512usize, 12usize);

    println!("distributing a {h}x{w} diffusion-2D grid ({iters} iters) across devices:\n");
    println!("workers | Mcell/s | halo cells moved | comm/compute | max|err| vs oracle");

    let mut base = Grid::new2d(h, w);
    base.fill_gaussian(0.0, 1.0, 0.06);
    let want = reference::run(kind, &base, None, kind.def().default_coeffs, iters);

    for workers in [1usize, 2, 4, 8] {
        let plan = PlanBuilder::new(kind)
            .grid_dims(vec![h, w])
            .iterations(iters)
            .tile(vec![64, 64])
            .backend(Backend::Vec { par_vec: 8 })
            .build()?;
        let mut grid = base.clone();
        let rep = ClusterCoordinator::new(plan, workers)
            .launcher(WorkerLauncher::Threads)
            .run(&mut grid, None)
            .map_err(anyhow::Error::new)?;
        let err = grid.max_abs_diff(&want);
        let comm_ratio = rep.halo_cells_exchanged as f64 / rep.cell_updates as f64;
        println!(
            "{workers:>7} | {:>7.1} | {:>16} | {comm_ratio:>12.4} | {err:.3e}",
            rep.mcells_per_s(),
            rep.halo_cells_exchanged,
        );
        anyhow::ensure!(err < 1e-3, "distributed run deviates");
    }

    println!(
        "\nnote: halo volume grows with workers but comm/compute stays tiny — \
         the scaling headroom §8 anticipates. Temporal-only prior work cannot \
         distribute at all (each PE needs the whole row)."
    );
    println!("multi_fpga OK");
    Ok(())
}
