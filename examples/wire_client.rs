//! The wire front door, end to end in one process: bind a
//! [`WireFrontend`] over a shared engine pool, speak the TCP job
//! protocol to it with [`WireClient`], and show that wire tenants and
//! in-process sessions multiplex onto the SAME worker pool under one
//! fairness discipline.
//!
//! The paper's serving model (§3.2: configure once, invoke many times)
//! stops at the host API boundary; the wire layer extends it across a
//! socket — length-prefixed JSON frames, base64 grid payloads, a durable
//! job ledger with retry — without touching the numerics: results are
//! bit-identical to an in-process run of the same plan.
//!
//!     cargo run --release --example wire_client

use fstencil::engine::wire::{PlanSpec, WaitOutcome, WireClient, WireConfig, WireFrontend};
use fstencil::engine::{StencilEngine, Workload};
use fstencil::prelude::*;

fn main() -> anyhow::Result<()> {
    // One shared pool behind the front door. `127.0.0.1:0` picks an
    // ephemeral port; sandboxes without loopback skip gracefully.
    let server = StencilEngine::new().serve(4);
    let mut front = match WireFrontend::bind("127.0.0.1:0", server, WireConfig::default()) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("SKIP: loopback unavailable in this environment ({e})");
            return Ok(());
        }
    };
    let addr = front.local_addr().to_string();
    println!("front door listening on {addr}");

    // A wire tenant: open a session by shipping the plan as JSON, submit
    // a grid (LE-f32 bytes in base64), wait for the result.
    let plan = PlanBuilder::new(StencilKind::Diffusion2D)
        .grid_dims(vec![192, 192])
        .iterations(12)
        .backend(Backend::Vec { par_vec: 8 })
        .build()?;
    let spec = PlanSpec::from_plan(&plan);
    let mut client = WireClient::connect(&addr)?;
    let session = client.open(spec, vec![])?;

    let mut input = Grid::new2d(192, 192);
    input.fill_random(7, 0.0, 1.0);
    let job = client.submit(session, &input, None, None)?;
    println!("submitted wire job {job}");

    // Meanwhile an in-process tenant shares the same pool: the wire is a
    // front door, not a separate engine.
    let local = front.open_local(plan)?;
    let mut local_in = input.clone();
    local_in.fill_random(8, 0.0, 1.0);
    let local_out = local.submit(Workload::new(local_in))?.wait()?;
    println!(
        "in-process tenant ran {} tiles on the same pool",
        local_out.report.tiles_executed
    );

    let wire_grid = match client.wait_result(job, std::time::Duration::from_secs(120))? {
        WaitOutcome::Done { grid, attempts, .. } => {
            println!("wire job {job} done (attempt {attempts})");
            grid
        }
        other => anyhow::bail!("wire job ended unexpectedly: {other:?}"),
    };

    // Bit-identity: the socket may not perturb the numerics.
    let mut oracle = StencilEngine::new().session(
        PlanBuilder::new(StencilKind::Diffusion2D)
            .grid_dims(vec![192, 192])
            .iterations(12)
            .backend(Backend::Vec { par_vec: 8 })
            .build()?,
    )?;
    let want = oracle.submit(input).wait()?.grid;
    anyhow::ensure!(
        wire_grid.data().iter().zip(want.data()).all(|(a, b)| a.to_bits() == b.to_bits()),
        "wire result is not bit-identical to the in-process run"
    );
    println!("wire result is bit-identical to the in-process run");

    // Per-tenant wire accounting rides on the same stats surface.
    let stats = client.stats(session)?;
    println!("tenant stats: {stats}");

    client.close_session(session)?;
    front.shutdown();
    println!("wire example OK");
    Ok(())
}
