//! §5.3 in action: parameter tuning for every stencil on both evaluation
//! boards, showing the candidate shortlist (the paper keeps <6 per stencil
//! per board), the measured winner, and the §6.1 resource-allocation
//! conclusions.
//!
//!     cargo run --release --example dse_tuning

use fstencil::dse::Tuner;
use fstencil::simulator::{Device, DeviceKind};
use fstencil::stencil::StencilKind;

fn main() {
    for devk in [DeviceKind::StratixV, DeviceKind::Arria10] {
        let dev = Device::get(devk);
        println!("\n================ {} ================", dev.name);
        for kind in StencilKind::ALL {
            let dims = if kind.ndim() == 2 {
                vec![16096, 16096]
            } else {
                vec![696, 696, 696]
            };
            let Some(out) = Tuner::new(devk).tune(kind, &dims, 1000) else {
                println!("{kind}: no feasible configuration");
                continue;
            };
            println!("\n--- {kind} ({} candidates after model+area pruning) ---", out.candidates.len());
            for (i, m) in out.measured.iter().enumerate() {
                let mark = if i == out.best { " <- best" } else { "" };
                println!(
                    "  bsize {:>4} par_vec {:>2} par_time {:>2} | fmax {:>5.1} | {:>6.1} GB/s | \
                     logic {:>3.0}% mem {:>3.0}% dsp {:>3.0}%{mark}",
                    m.params.bsize_x,
                    m.params.par_vec,
                    m.params.par_time,
                    m.params.fmax_mhz,
                    m.measured_gbps,
                    m.area.logic_frac * 100.0,
                    m.area.bram_blocks_frac * 100.0,
                    m.area.dsp_frac * 100.0,
                );
            }
            let t = &out.tuned;
            println!(
                "  tuned (seed sweep): {:.1} MHz -> {:.1} GB/s = {:.1} GFLOP/s, {:.1} W, accuracy {:.0}%",
                t.params.fmax_mhz,
                t.measured_gbps,
                t.measured_gflops,
                t.power_w,
                t.model_accuracy * 100.0
            );
        }
    }
    println!(
        "\n§6.1 takeaway check: 2D winners run deep PE chains (par_time >> par_vec); \
         3D winners spend the area on vector width instead."
    );
}
