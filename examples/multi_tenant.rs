//! Multi-tenant serving: three clients — different stencils, different
//! backends — share ONE engine worker pool.
//!
//! The paper's accelerator keeps a single deeply pipelined PE chain busy
//! by streaming blocks through it (§3.2, Fig 2); the host `EngineServer`
//! treats that capacity as a shared resource: a deficit-round-robin
//! scheduler interleaves every client's tiles at chunk granularity, so a
//! large 3-D job cannot starve small 2-D jobs, while each client keeps
//! its own warm plan state (geometry cache + grid double-buffer).
//!
//!     cargo run --release --example multi_tenant

use fstencil::engine::Workload;
use fstencil::prelude::*;
use fstencil::stencil::reference;

fn main() -> anyhow::Result<()> {
    // ONE shared pool: 4 compute workers + a scheduler, spawned once.
    let server = StencilEngine::new().serve(4);

    // Tenant 1: vectorized 2-D diffusion.
    let diffusion = server.open(
        PlanBuilder::new(StencilKind::Diffusion2D)
            .grid_dims(vec![192, 192])
            .iterations(12)
            .backend(Backend::Vec { par_vec: 8 })
            .build()?,
    )?;
    // Tenant 2: Hotspot 2D (power input) on the streaming cascade.
    let hotspot = server.open(
        PlanBuilder::new(StencilKind::Hotspot2D)
            .grid_dims(vec![128, 128])
            .iterations(8)
            .backend(Backend::Stream { par_vec: 4 })
            .build()?,
    )?;
    // Tenant 3: a big 3-D job on the scalar oracle — the "heavy" tenant
    // the scheduler must not let monopolize the pool.
    let volume = server.open(
        PlanBuilder::new(StencilKind::Diffusion3D)
            .grid_dims(vec![32, 32, 32])
            .iterations(6)
            .build()?,
    )?;

    // Submit concurrently from three client threads (each owns its
    // session), then verify every result against the scalar oracle.
    let mk = |ndim: usize, dims: &[usize], seed: u64| {
        let mut g = if ndim == 2 {
            Grid::new2d(dims[0], dims[1])
        } else {
            Grid::new3d(dims[0], dims[1], dims[2])
        };
        g.fill_random(seed, 0.0, 1.0);
        g
    };
    let threads = [
        std::thread::spawn(move || -> anyhow::Result<(String, bool)> {
            let mut ok = true;
            for seed in 0..3u64 {
                let input = mk(2, &[192, 192], seed);
                let want = reference::run(
                    StencilKind::Diffusion2D,
                    &input,
                    None,
                    StencilKind::Diffusion2D.def().default_coeffs,
                    12,
                );
                let out = diffusion.submit(input)?.wait()?;
                ok &= out.grid.max_abs_diff(&want) < 1e-3;
            }
            let s = diffusion.stats();
            Ok((format!(
                "diffusion2d vec:8  — {} jobs, {} tiles, max queue wait {:.2} ms",
                s.jobs_completed,
                s.tiles_executed,
                s.max_queue_wait.as_secs_f64() * 1e3
            ), ok))
        }),
        std::thread::spawn(move || -> anyhow::Result<(String, bool)> {
            let mut ok = true;
            for seed in 10..13u64 {
                let input = mk(2, &[128, 128], seed);
                let mut power = input.clone();
                power.fill_random(seed + 100, 0.0, 0.25);
                let want = reference::run(
                    StencilKind::Hotspot2D,
                    &input,
                    Some(&power),
                    StencilKind::Hotspot2D.def().default_coeffs,
                    8,
                );
                let out = hotspot.submit(Workload::new(input).power(power))?.wait()?;
                ok &= out.grid.max_abs_diff(&want) < 1e-3;
            }
            let s = hotspot.stats();
            Ok((format!(
                "hotspot2d stream:4 — {} jobs, {} tiles, max queue wait {:.2} ms",
                s.jobs_completed,
                s.tiles_executed,
                s.max_queue_wait.as_secs_f64() * 1e3
            ), ok))
        }),
        std::thread::spawn(move || -> anyhow::Result<(String, bool)> {
            let input = mk(3, &[32, 32, 32], 42);
            let want = reference::run(
                StencilKind::Diffusion3D,
                &input,
                None,
                StencilKind::Diffusion3D.def().default_coeffs,
                6,
            );
            let out = volume.submit(input)?.wait()?;
            let ok = out.grid.max_abs_diff(&want) < 1e-3;
            let s = volume.stats();
            Ok((format!(
                "diffusion3d scalar — {} jobs, {} tiles, max queue wait {:.2} ms",
                s.jobs_completed,
                s.tiles_executed,
                s.max_queue_wait.as_secs_f64() * 1e3
            ), ok))
        }),
    ];
    let mut all_ok = true;
    for t in threads {
        let (line, ok) = t.join().expect("client thread panicked")?;
        println!("{line}");
        all_ok &= ok;
    }
    println!(
        "shared pool: {} compute threads (spawned once), {} fresh tile buffers (cap {})",
        server.threads_spawned(),
        server.fresh_tile_allocs(),
        server.tile_pool_capacity(),
    );
    anyhow::ensure!(all_ok, "a tenant's results deviated from the scalar oracle");
    println!("multi-tenant OK: all tenants bit-for-bit busy on one pool");
    Ok(())
}
