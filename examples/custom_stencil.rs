//! Define a brand-new stencil at runtime — no enum edit, no recompile of
//! the framework's kernels — and run it through a warm engine session on
//! the vectorized backend, checking it against its scalar interpreter
//! oracle.
//!
//!     cargo run --release --example custom_stencil
//!
//! The same program could instead be loaded from JSON:
//!
//!     fstencil run --stencil-file stencils/vonneumann_r3.json \
//!         --stencil vonneumann_r3 --backend vec --check

use fstencil::prelude::*;
use fstencil::stencil::reference;

fn main() -> anyhow::Result<()> {
    // A 9-point anisotropic radius-2 star, defined in ~10 lines of data.
    let program = StencilProgram::builder("aniso_star_r2", 2)
        .tap(&[0, 0], 0) // center
        .tap(&[-1, 0], 1) // north
        .tap(&[1, 0], 2) // south
        .tap(&[0, -1], 3) // west
        .tap(&[0, 1], 4) // east
        .tap(&[-2, 0], 5) // far north (vertical diffuses farther)
        .tap(&[2, 0], 6) // far south
        .default_coeffs(vec![0.5, 0.14, 0.14, 0.08, 0.08, 0.03, 0.03])
        .build()?;
    let stencil: StencilId = StencilRegistry::register(program)?;
    println!(
        "registered '{stencil}': radius {}, {} FLOP/cell, {} B/cell",
        stencil.def().radius,
        stencil.def().flop_pcu,
        stencil.def().bytes_pcu
    );

    // Runtime-defined programs plan and run exactly like built-ins.
    let dims = vec![256usize, 256];
    let iters = 12;
    let plan = PlanBuilder::new(stencil)
        .grid_dims(dims.clone())
        .iterations(iters)
        .backend(Backend::Vec { par_vec: 8 })
        .build()?;
    let mut session = StencilEngine::new().session(plan.clone())?;

    let mut grid = Grid::new2d(dims[0], dims[1]);
    grid.fill_gaussian(300.0, 50.0, 0.08);
    let before = grid.clone();
    let out = session.submit(grid).wait()?;
    println!(
        "ran {iters} iters on {}: {} tiles, {:.1} Mcell/s",
        out.report.backend,
        out.report.tiles_executed,
        out.report.mcells_per_sec()
    );

    // The scalar generic interpreter is the oracle for custom programs:
    // a scalar-backend session must be bit-identical, the whole-grid
    // interpreter within fp tolerance (same bar the built-ins meet).
    let scalar_plan = PlanBuilder::new(stencil)
        .grid_dims(dims)
        .iterations(iters)
        .build()?;
    let mut oracle_grid = before.clone();
    StencilEngine::new().run(scalar_plan, &mut oracle_grid, None)?;
    let bit_identical = out
        .grid
        .data()
        .iter()
        .zip(oracle_grid.data())
        .all(|(a, b)| a.to_bits() == b.to_bits());
    anyhow::ensure!(bit_identical, "vec backend deviated from the scalar interpreter");
    let want = reference::run(stencil, &before, None, &plan.coeffs, iters);
    let err = out.grid.max_abs_diff(&want);
    println!("max |err| vs whole-grid interpreter oracle: {err:.3e}");
    anyhow::ensure!(err < 1e-3, "custom stencil deviated from its oracle");
    println!("custom stencil OK (vec session bit-identical to the scalar session)");
    Ok(())
}
