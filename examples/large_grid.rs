//! The paper's headline capability: UNRESTRICTED input sizes.
//!
//! Prior deep-pipelined FPGA stencil work ([9, 20, 22] in the paper)
//! avoids spatial blocking, so each PE's shift register must span the
//! whole input width — capping 2D widths at a few thousand cells and 3D
//! planes at ~128x128. This example demonstrates, on every layer of our
//! stack, that combined blocking removes the cap:
//!
//! 1. Shows the temporal-only baseline's width limit on both boards.
//! 2. Runs a real 2048x2048 Diffusion 2D workload (wider than the
//!    temporal-only Stratix V design can hold at par_time 24) through the
//!    blocked PJRT/host pipeline and verifies the numerics.
//! 3. Simulates the paper-scale 16096^2 workload on the board simulator
//!    and reports the Table-4-style projection.
//!
//!     cargo run --release --example large_grid

use fstencil::baseline::max_supported_width;
use fstencil::coordinator::PlanBuilder;
use fstencil::engine::{Backend, StencilEngine};
use fstencil::model::Params;
use fstencil::simulator::{BoardSim, Device, DeviceKind};
use fstencil::stencil::{reference, Grid, StencilKind};

fn main() -> anyhow::Result<()> {
    let kind = StencilKind::Diffusion2D;

    // --- 1. the prior-work restriction -------------------------------
    // Prior work's performance comes from DEEP temporal chains (tens of
    // PEs); that is exactly where the missing spatial blocking caps the
    // input size (§1: "a few thousand cells" wide for 2D, 128x128 planes
    // for 3D).
    println!("temporal-only baseline (no spatial blocking) input caps:");
    for devk in [DeviceKind::StratixV, DeviceKind::Arria10] {
        let dev = Device::get(devk);
        for par_time in [8, 24, 64, 96] {
            let cap = max_supported_width(kind, dev, 8, par_time);
            println!(
                "  {:<18} 2D par_time {par_time:>2}: max width {cap} cells",
                dev.name
            );
        }
        let cap3d = max_supported_width(StencilKind::Diffusion3D, dev, 8, 8);
        println!("  {:<18} 3D par_time  8: max plane {cap3d}x{cap3d} cells", dev.name);
    }
    let sv = Device::get(DeviceKind::StratixV);
    let cap96 = max_supported_width(kind, sv, 8, 96);
    println!(
        "  -> at the deep chains prior work relies on (par_time 96), a 16096-wide \
         paper-scale grid {} the Stratix V temporal-only design (cap: {cap96})\n",
        if 16096 > cap96 { "DOES NOT FIT" } else { "fits" }
    );

    // --- 2. real numerics on a wide grid through the blocked stack ----
    let (h, w, iters) = (2048usize, 2048usize, 8usize);
    println!("running {h}x{w} diffusion-2D x{iters} through the blocked pipeline...");
    let mut grid = Grid::new2d(h, w);
    grid.fill_gaussian(0.0, 1.0, 0.05);
    let before = grid.clone();
    let plan = PlanBuilder::new(kind)
        .grid_dims(vec![h, w])
        .iterations(iters)
        .tile(vec![128, 128])
        .step_sizes(vec![4, 2, 1])
        .backend(Backend::Vec { par_vec: 8 })
        .build()?;
    let rep = StencilEngine::new().session(plan.clone())?.run(&mut grid, None)?;
    println!(
        "  {} tiles, {} passes, {:.2}s -> {:.1} Mcell/s (redundancy {:.3})",
        rep.tiles_executed,
        rep.passes,
        rep.elapsed.as_secs_f64(),
        rep.mcells_per_sec(),
        rep.redundancy()
    );
    // verify a full oracle run
    let want = reference::run(kind, &before, None, &plan.coeffs, iters);
    let err = grid.max_abs_diff(&want);
    println!("  max |err| vs oracle = {err:.3e}");
    anyhow::ensure!(err < 1e-3, "verification failed");

    // --- 3. paper-scale projection on the board simulator -------------
    println!("\npaper-scale (16096^2, 1000 iters) on the Arria 10 simulator:");
    let sim = BoardSim::new(DeviceKind::Arria10);
    let p = Params::new(kind, 8, 36, 4096, &[16096, 16096], 1000, 0.0);
    let r = sim.simulate(&p).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "  bsize 4096 / par_vec 8 / par_time 36 @ {:.1} MHz -> {:.1} GB/s = {:.1} GFLOP/s \
         (paper measured: 674.0 GB/s = 758.2 GFLOP/s)",
        r.params.fmax_mhz, r.measured_gbps, r.measured_gflops
    );
    println!(
        "  run time for the full workload: {:.2}s simulated (paper: ~3s class), power {:.1} W",
        r.run_time_s, r.power_w
    );
    println!("large_grid OK");
    Ok(())
}
