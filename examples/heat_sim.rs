//! End-to-end driver (DESIGN.md §5): a realistic Hotspot 2D thermal
//! simulation of a chip floorplan, run through ALL layers of the stack —
//! Pallas-authored kernels → AOT HLO artifacts → PJRT CPU client → Rust
//! coordinator with overlapped blocking — on a real small workload, with
//! the convergence curve logged and the result verified against the
//! scalar oracle.
//!
//!     make artifacts && cargo run --release --example heat_sim
//!
//! Without artifacts the checkpointed loop runs on ONE warm engine
//! session: the same worker threads, tile pools and grid pair serve
//! every 25-step checkpoint (the paper's program-once / invoke-many
//! contract — each checkpoint is just another kernel invocation).
//!
//! The floorplan models a 4-core die: hot cores in the corners, a warm
//! L3 slab in the middle, cool I/O at the edges (the workload class the
//! paper's intro motivates: thermal simulation on Rodinia's Hotspot).

use fstencil::coordinator::{Coordinator, ExecReport, PlanBuilder};
use fstencil::engine::{Backend, StencilEngine, Workload};
use fstencil::runtime::PjrtExecutor;
use fstencil::stencil::{reference, Grid, StencilKind};

const N: usize = 384; // die resolution (N x N cells)
const AMB: f32 = 80.0; // Rodinia-style ambient, in arbitrary units

/// Build a 4-core chip power map.
fn floorplan(n: usize) -> Grid {
    let mut p = Grid::new2d(n, n);
    let core = n / 4;
    let put = |p: &mut Grid, y0: usize, x0: usize, h: usize, w: usize, v: f32| {
        for y in y0..(y0 + h).min(n) {
            for x in x0..(x0 + w).min(n) {
                p.set(0, y, x, v);
            }
        }
    };
    // four cores
    for (cy, cx) in [(n / 8, n / 8), (n / 8, 5 * n / 8), (5 * n / 8, n / 8), (5 * n / 8, 5 * n / 8)]
    {
        put(&mut p, cy, cx, core, core, 1.8);
    }
    // L3 slab in the center
    put(&mut p, 3 * n / 8, 3 * n / 8, n / 4, n / 4, 0.6);
    p
}

fn main() -> anyhow::Result<()> {
    let kind = StencilKind::Hotspot2D;
    let coeffs = kind.def().default_coeffs.to_vec();
    let iters_total = 200;
    let checkpoint = 25;

    let mut temp = Grid::new2d(N, N);
    temp.fill_const(AMB);
    let power = floorplan(N);

    // One runner for the whole trajectory: the PJRT artifact path when
    // available, otherwise a single warm engine session that every
    // checkpoint reuses (threads + buffers spawned once, before step 0).
    let coeffs_r = coeffs.clone();
    let power_r = power.clone();
    let mut runner: Box<dyn FnMut(&mut Grid, usize) -> anyhow::Result<ExecReport>> =
        match PjrtExecutor::load_default() {
            Ok(p) => {
                println!("backend: PJRT ({})", p.platform());
                Box::new(move |g, step| {
                    let plan = PlanBuilder::new(kind)
                        .grid_dims(vec![N, N])
                        .iterations(step)
                        .coeffs(coeffs_r.clone())
                        .for_executor(&p)
                        .build()?;
                    Coordinator::new(plan).run(&p, g, Some(&power_r))
                })
            }
            Err(e) => {
                println!("backend: warm engine session, vec:8 ({e})");
                let plan = PlanBuilder::new(kind)
                    .grid_dims(vec![N, N])
                    .iterations(checkpoint)
                    .coeffs(coeffs_r)
                    .backend(Backend::Vec { par_vec: 8 })
                    .build()?;
                let mut session = StencilEngine::new().session(plan)?;
                Box::new(move |g, step| {
                    let owned = std::mem::replace(g, Grid::new2d(1, 1));
                    let out = session
                        .submit(
                            Workload::new(owned).power(power_r.clone()).iterations(step),
                        )
                        .wait()?;
                    *g = out.grid;
                    Ok(out.report)
                })
            }
        };

    println!("thermal simulation: {N}x{N} die, {iters_total} time-steps");
    println!("step | t_max    t_mean   | hottest-core delta | Mcell/s");
    let t0 = std::time::Instant::now();
    let mut done = 0;
    let mut tiles = 0u64;
    while done < iters_total {
        let step = checkpoint.min(iters_total - done);
        let rep = runner(&mut temp, step)?;
        tiles += rep.tiles_executed;
        done += step;
        let tmax = temp.data().iter().cloned().fold(f32::MIN, f32::max);
        let tmean = temp.sum() as f32 / (N * N) as f32;
        println!(
            "{done:>4} | {tmax:>8.3} {tmean:>8.3} | {:>18.3} | {:>7.1}",
            tmax - AMB,
            rep.mcells_per_sec()
        );
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let updates = (N * N * iters_total) as f64;
    println!(
        "\ntotal: {updates:.0} cell updates, {tiles} tiles, {elapsed:.2}s -> {:.1} Mcell/s end-to-end",
        updates / elapsed / 1e6
    );

    // Full verification of the entire 200-step trajectory.
    print!("verifying against the scalar oracle ... ");
    let mut check = Grid::new2d(N, N);
    check.fill_const(AMB);
    let want = reference::run(kind, &check, Some(&power), &coeffs, iters_total);
    let err = temp.max_abs_diff(&want);
    println!("max |err| = {err:.3e}");
    anyhow::ensure!(err < 5e-3, "verification failed");

    // Physics: cores hotter than L3, L3 hotter than idle silicon.
    let t_core = temp.get(0, N / 8 + N / 8, N / 8 + N / 8);
    let t_l3 = temp.get(0, N / 2, N / 2);
    let t_edge = temp.get(0, 1, N / 2);
    println!("core {t_core:.2} > L3 {t_l3:.2} > edge {t_edge:.2}");
    anyhow::ensure!(t_core > t_l3 && t_l3 > t_edge, "thermal ordering violated");
    println!("heat_sim OK");
    Ok(())
}
